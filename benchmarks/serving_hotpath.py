"""Serving hot-path A/B: seed-style path vs the pipelined zero-copy engine.

Overhead-dominated regime (paper §IV.A): M=4 fake workers sharing ONE device,
so prediction costs ~nothing and the measurement isolates the serving machinery
— batching, queues, transfers, combination.  Compares:

  * ``seed``      per-member messages (``device_combine=False``), one request
                  in flight (``max_in_flight=1``) — the seed's behavior;
  * ``pipelined`` device-resident partial combine + multi-request in-flight
                  window — one accumulator message per device per segment.

Reports segments/sec, accumulator messages per request, and per-stage timings.
Acceptance (ISSUE 1): pipelined >= 1.5x seed segments/sec, and messages per
request drop from M x segments to devices x segments.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.seed_baseline import SeedSystem
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving import segments as seg

GiB = 1024 ** 3


def _measure(system, X, requests: int, pipelined: bool) -> dict:
    n_segments = seg.num_segments(X.shape[0], system.segment_size)
    system.predict(X)                      # warm
    if pipelined:
        system.timers.reset()
    msg0 = system.accumulator.data_messages
    t0 = time.perf_counter()
    if pipelined:                          # overlap through the window
        handles = [system.predict_async(X) for _ in range(requests)]
        for h in handles:
            h.result(600.0)
    else:                                  # seed path: requests serialize
        for _ in range(requests):
            system.predict(X)
    dt = time.perf_counter() - t0
    return {
        "requests": requests,
        "segments_per_request": n_segments,
        "seconds": dt,
        "segments_per_sec": requests * n_segments / dt,
        "samples_per_sec": requests * X.shape[0] / dt,
        "messages_per_request":
            (system.accumulator.data_messages - msg0) / requests,
        "stage_timings": system.stage_timings() if pipelined else {},
    }


def run(csv=True, n_samples=2048, seq=16, requests=24, workers=4):
    import jax
    import repro.models as M
    from repro.serving.system import InferenceSystem

    cfgs = ensemble("ENS4")[:workers]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    devs = host_cpus(1, memory_bytes=8 * GiB)       # ONE shared device
    A = np.full((1, len(cfgs)), 8)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    X = np.random.default_rng(0).integers(0, 512, (n_samples, seq)).astype(np.int32)

    results = {}
    with SeedSystem(cfgs, alloc, max_seq=seq) as system:
        results["seed"] = _measure(system, X, requests, pipelined=False)
    with InferenceSystem(cfgs, params, alloc, segment_size=128,
                         max_seq=seq, fake=True, device_combine=True,
                         max_in_flight=4) as system:
        results["pipelined"] = _measure(system, X, requests, pipelined=True)

    speedup = (results["pipelined"]["segments_per_sec"] /
               results["seed"]["segments_per_sec"])
    results["speedup"] = speedup
    if csv:
        print("serving_hotpath:variant,segments_per_sec,messages_per_request")
        for name in ("seed", "pipelined"):
            r = results[name]
            print(f"serving_hotpath:{name},{r['segments_per_sec']:.1f},"
                  f"{r['messages_per_request']:.1f}")
        print(f"serving_hotpath:speedup,{speedup:.2f},")
        for name in ("seed", "pipelined"):
            for stage, t in results[name]["stage_timings"].items():
                print(f"serving_hotpath:{name}.{stage},"
                      f"{t['total_s']:.4f},{t['count']}")
    return results


if __name__ == "__main__":
    run()
