"""The asynchronous inference system (paper §II): segment broadcaster,
worker pool, prediction accumulator, HTTP wrapper."""
from repro.serving.accumulator import PredictionAccumulator
from repro.serving.segments import DEFAULT_SEGMENT_SIZE, Message
from repro.serving.server import AdaptiveBatcher, serve
from repro.serving.system import InferenceSystem
from repro.serving.worker import Worker, make_predict_fn

__all__ = ["InferenceSystem", "Worker", "make_predict_fn", "Message",
           "PredictionAccumulator", "AdaptiveBatcher", "serve",
           "DEFAULT_SEGMENT_SIZE"]
