"""Pallas TPU flash-decoding: one query token against a long KV cache.

Tiling: grid = (batch, q_heads, num_kv_blocks); the kv-block dim is the
innermost, sequential grid dim, so the online-softmax running state lives in
VMEM scratch.  The query block (a single token per (b,h)) is tiny; the kernel
streams (BLOCK_KV, head_dim) cache tiles through VMEM — this is the
HBM-bandwidth-bound op that dominates decode_32k/long_500k rooflines.

A validity mask (int32, 1/0 per slot) handles ring-buffer SWA caches and
not-yet-filled slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_KV = 512


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            num_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    ok = valid_ref[0] > 0                          # (bkv,)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)[0]   # (bkv,)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[0] = l_ref[0] * alpha + p.sum()
    acc_ref[...] = acc_ref[...] * alpha + \
        jnp.dot(p[None, :], v, preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, block_kv: int = BLOCK_KV,
                     interpret: bool = False) -> jax.Array:
    """q: (B,1,H,hd); k/v: (B,L,KV,hd); valid: (L,) int32.

    L and hd must already be padded (ops.py).  Returns (B,1,H,hd).
    """
    b, _, h, hd = q.shape
    L, kv = k.shape[1], k.shape[2]
    group = h // kv
    block_kv = min(block_kv, L)
    assert L % block_kv == 0
    nk = L // block_kv

    qt = q.transpose(0, 2, 1, 3)                   # (B,H,1,hd)
    kt = k.transpose(0, 2, 1, 3)                   # (B,KV,L,hd)
    vt = v.transpose(0, 2, 1, 3)
    valid_i = valid.astype(jnp.int32).reshape(nk, block_kv)

    kernel = functools.partial(_kernel, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b_, h_, k_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b_, h_, k_: (b_, h_ // group, k_, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b_, h_, k_: (b_, h_ // group, k_, 0)),
            pl.BlockSpec((1, block_kv), lambda b_, h_, k_: (k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b_, h_, k_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, valid_i)
    return out.transpose(0, 2, 1, 3)
