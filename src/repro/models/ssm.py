"""Mamba2 mixer: chunked SSD (state-space duality) forward + O(1) decode step.

Faithful to arXiv:2405.21060 (single B/C group): in_proj -> [z, x, B, C, dt],
short causal depthwise conv over [x,B,C], softplus dt, scalar-per-head A,
chunked dual computation (intra-chunk attention-like term + inter-chunk state
recurrence), gated RMSNorm, out_proj.

The pure-jnp chunked scan here is also the oracle for the Pallas ``ssd_scan``
kernel (repro/kernels/ssd_scan.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm.d_state, cfg.ssm_heads
    z, x, bmat, cmat, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, bmat, cmat, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xbc: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out)


def segsum_exp(dA_cs: jax.Array) -> jax.Array:
    """L[..., i, j] = exp(cs_i - cs_j) for i >= j else 0.  dA_cs: (..., cl)."""
    diff = dA_cs[..., :, None] - dA_cs[..., None, :]
    cl = dA_cs.shape[-1]
    mask = jnp.tril(jnp.ones((cl, cl), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, bmat, cmat, chunk: int):
    """The SSD dual-form scan (pure jnp reference).

    x: (B,S,H,P) float32, dt: (B,S,H) (post-softplus), A: (H,) negative,
    bmat/cmat: (B,S,N).  Returns y: (B,S,H,P).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    dA = dtc * A                                        # (b,nc,cl,h)
    cs = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum
    # --- intra-chunk (attention-like) term
    L = segsum_exp(cs.transpose(0, 1, 3, 2))            # (b,nc,h,cl,cl)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)      # (b,nc,cl,cl)
    gated = scores[:, :, None] * L                      # (b,nc,h,cl,cl)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", gated, dtc, xc)
    # --- per-chunk final states
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)       # (b,nc,cl,h)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc,
                        dtc * decay_to_end, xc)         # (b,nc,h,p,n)
    # --- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cs[:, :, -1, :])              # (b,nc,h)

    def body(hprev, inp):
        st, dec = inp                                   # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    from repro import runtime_flags
    _, hprevs = jax.lax.scan(body, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
                             unroll=runtime_flags.scan_unroll())
    hprevs = hprevs.swapaxes(0, 1)                      # (b,nc,h,p,n) state entering chunk
    y_inter = jnp.einsum("bcin,bchpn->bcihp", cc, hprevs) * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)
    return y[:, :s]


def ssd_final_state(x, dt, A, bmat, chunk: int):
    """Final SSM state after a prefill — (B,H,P,N), for handing off to decode."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    dA = dtc * A
    cs = jnp.cumsum(dA, axis=2)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, dtc * decay_to_end, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])

    def body(hprev, inp):
        st, dec = inp
        return hprev * dec[..., None, None] + st, None

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    from repro import runtime_flags
    hfinal, _ = jax.lax.scan(body, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
                             unroll=runtime_flags.scan_unroll())
    return hfinal


def _gated_norm(y, z, w, eps):
    y = y * jax.nn.silu(z)
    dt = y.dtype
    y = y.astype(jnp.float32)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def ssm_mixer(cfg: ModelConfig, p, xin: jax.Array, *, use_kernel: bool = False,
              return_state: bool = False):
    """Full-sequence Mamba2 mixer.  xin: (B,S,D) -> (B,S,D) [, final_state]."""
    s = cfg.ssm
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["in_proj"])
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    xbc_pre = jnp.concatenate([x, bmat, cmat], -1)       # pre-conv, for state handoff
    xbc = _causal_conv(xbc_pre, p["conv_w"])
    di, n = cfg.d_inner, s.d_state
    x, bmat, cmat = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    bsz, slen = xin.shape[0], xin.shape[1]
    x = x.reshape(bsz, slen, cfg.ssm_heads, s.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if use_kernel:
        from repro.kernels import ops as kops
        y = kops.ssd_scan(x, dt, A, bmat.astype(jnp.float32),
                          cmat.astype(jnp.float32), chunk=s.chunk)
    else:
        y = ssd_chunked(x, dt, A, bmat.astype(jnp.float32),
                        cmat.astype(jnp.float32), s.chunk)
    y = y + x * p["D"][None, None, :, None]
    y = y.reshape(bsz, slen, di).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        hfinal = ssd_final_state(x, dt, A, bmat.astype(jnp.float32), s.chunk)
        k = s.d_conv
        tail = xbc_pre[:, -(k - 1):]                     # (B, d_conv-1, C)
        pad = (k - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, hfinal, tail
    return out


def ssm_decode_step(cfg: ModelConfig, p, xin: jax.Array, h_state: jax.Array,
                    conv_state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token SSM step.

    xin: (B,1,D); h_state: (B,H,P,N) float32; conv_state: (B, d_conv-1, C).
    Returns (out (B,1,D), h_state', conv_state').
    """
    s = cfg.ssm
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["in_proj"])
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([x, bmat, cmat], -1)[:, 0]            # (B,C)
    window = jnp.concatenate([conv_state, xbc_new[:, None]], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu((window * p["conv_w"][None]).sum(axis=1))  # (B,C)
    new_conv_state = window[:, 1:]
    di, n = cfg.d_inner, s.d_state
    xt = conv_out[:, :di].reshape(-1, cfg.ssm_heads, s.head_dim).astype(jnp.float32)
    bt = conv_out[:, di:di + n].astype(jnp.float32)                 # (B,N)
    ct = conv_out[:, di + n:].astype(jnp.float32)
    dtt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtt * A)                                        # (B,H)
    h_state = h_state * decay[..., None, None] + \
        jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
    y = jnp.einsum("bn,bhpn->bhp", ct, h_state)
    y = y + xt * p["D"][None, :, None]
    y = y.reshape(xin.shape[0], 1, di).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, h_state, new_conv_state
