"""HTTP v2 on the EnsembleClient facade (paper §II.A, DESIGN.md §7).

Endpoints (stdlib only):
  POST /v2/predict  body: {"tokens": [[...], ...],
                           "priority": "high"|"normal",       (optional)
                           "deadline_ms": float,              (optional)
                           "members": [model ids],            (optional)
                           "combine": "mean|weighted|vote|pallas",
                           "cache": "use|bypass|refresh"}     (optional)
                    -> {"predictions": [[...], ...]}
                       plus "quality" < 1.0 when the result is a degraded
                       partial-ensemble combine (DESIGN.md §10)
                    (504 when the deadline expires; 429 + Retry-After when
                    admission refuses the request — infeasible deadline at
                    the current pressure, or byte/row budget exhausted
                    (DESIGN.md §11); 503 + Retry-After when capacity is
                    transiently unavailable — quarantined member, retries
                    exhausted; both Retry-After values derive from the live
                    drain estimate; 400 on bad input)
  POST /predict     v1 compatibility shim: the original adaptive batcher —
                    requests buffered until a segment fills or ``max_wait_s``
                    elapses, then predicted as one batch (paper §I.B).  New
                    clients should POST /v2/predict: the system's own
                    coalescing scheduler already does cross-request batching
                    with per-request options honored.
  GET  /metrics     serving counters (padding efficiency, rows, batches,
                    spans), per-worker queue-depth gauges (+ the rolling
                    hp_p50_ms gauge), per-priority-class latency p50/p99
                    (from fixed-bucket log-scale histograms; raw buckets
                    under "latency_hist"), per-stage timings incl.
                    dispatch_wait.high/normal, cache hit rates (ROADMAP
                    item d).  With ``?format=prom`` — or an ``Accept``
                    header naming ``text/plain`` / ``openmetrics`` — the
                    same surface renders as Prometheus text exposition
                    0.0.4 (typed, labeled families; DESIGN.md §13)
  GET  /v2/trace    Chrome-trace / Perfetto JSON of the flight recorder
                    (DESIGN.md §13); ``?dumps=1`` returns the
                    anomaly-triggered dumps instead
  GET  /health      -> {"status": "ok", "workers": N}
  GET  /allocation  -> the allocation matrix
"""
from __future__ import annotations

import json
import queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Tuple

import numpy as np

import math

from repro.serving.client import EnsembleClient
from repro.serving.metrics import PROM_CONTENT_TYPE, prometheus_text
from repro.serving.segments import (DeadlineExceeded, Overloaded,
                                    PredictOptions, ServingUnavailable)
from repro.serving.system import InferenceSystem


class _Pending:
    def __init__(self, x: np.ndarray):
        self.x = x
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.cancelled = False          # submitter gave up (timeout)


class AdaptiveBatcher:
    """Buffers requests into segments; flushes on size or timeout.  Kept as
    the v1 ``/predict`` compatibility path — the v2 route goes straight
    through the facade and relies on the worker-level coalescing scheduler."""

    def __init__(self, system: InferenceSystem, *, max_wait_s: float = 0.05,
                 cache=None):
        self.system = system
        self.max_wait_s = max_wait_s
        self.cache = cache                  # optional PredictionCache
        self.q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def submit(self, x: np.ndarray, timeout: float = 120.0) -> np.ndarray:
        p = _Pending(x)
        self.q.put(p)
        if not p.event.wait(timeout):
            # mark so the flush loop drops it instead of predicting rows
            # nobody will collect (the timed-out _Pending used to stay in
            # the queue and still get predicted)
            p.cancelled = True
            raise TimeoutError("request timed out")
        return p.result

    def stop(self):
        self._stop.set()
        self._thread.join(5.0)

    def _run(self):
        target = self.system.segment_size
        while not self._stop.is_set():
            batch: List[_Pending] = []
            count = 0
            deadline = None
            while count < target:
                timeout = None if deadline is None else max(0.0, deadline - time.monotonic())
                try:
                    p = self.q.get(timeout=0.05 if deadline is None else timeout)
                except queue.Empty:
                    if deadline is None:
                        if self._stop.is_set():
                            return
                        continue
                    break                       # adaptive flush on timeout
                if deadline is None:
                    deadline = time.monotonic() + self.max_wait_s
                batch.append(p)
                count += p.x.shape[0]
            batch = [p for p in batch if not p.cancelled]   # timed-out waiters
            if not batch:
                continue
            X = np.concatenate([p.x for p in batch], axis=0)
            try:
                Y = (self.cache.predict_through(self.system, X)
                     if self.cache is not None else self.system.predict(X))
                off = 0
                for p in batch:
                    p.result = Y[off:off + p.x.shape[0]]
                    off += p.x.shape[0]
            except Exception:                   # surface errors to all waiters
                for p in batch:
                    p.result = None
            for p in batch:
                p.event.set()


def _header_s(retry_after_s: float) -> str:
    """``Retry-After`` header value: whole seconds, never below 1 (the
    header grammar is integer seconds; the JSON body carries the exact
    float for clients that can use sub-second backoff)."""
    return str(max(1, int(math.ceil(retry_after_s))))


def _parse_options(payload: dict) -> PredictOptions:
    """Per-request options from the v2 JSON body (unknown keys ignored)."""
    kw = {}
    if "priority" in payload:
        kw["priority"] = payload["priority"]
    if payload.get("deadline_ms") is not None:
        kw["deadline_ms"] = float(payload["deadline_ms"])
    if payload.get("members") is not None:
        kw["members"] = [int(m) for m in payload["members"]]
    if payload.get("combine") is not None:
        kw["combine"] = str(payload["combine"])
    if payload.get("cache") is not None:
        kw["cache"] = str(payload["cache"])
    return PredictOptions(**kw)


def serve(system: InferenceSystem, host: str = "127.0.0.1", port: int = 8600,
          *, max_wait_s: float = 0.05,
          cache=None) -> Tuple[ThreadingHTTPServer, AdaptiveBatcher]:
    """Start the HTTP server (returns immediately; server runs on a thread).
    ``cache``: optional serving.request_cache.PredictionCache (paper §I.B),
    shared by the v1 shim and the v2 facade."""
    batcher = AdaptiveBatcher(system, max_wait_s=max_wait_s, cache=cache)
    client = EnsembleClient(system, cache=cache)

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):              # quiet
            pass

        def _retry_after(self, e: BaseException) -> float:
            """Drain-estimate-derived backoff, shared by 429 and 503
            (DESIGN.md §11).  An exception that computed its own estimate
            at raise time (``Overloaded``) wins; otherwise ask the system
            now."""
            ra = getattr(e, "retry_after_s", None)
            if ra is None:
                ra = system.retry_after_s()
            return round(float(ra), 3)

        def _json(self, code: int, payload, headers=None):
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            for k, v in (headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)

        def _text(self, code: int, body: str, content_type: str):
            data = body.encode()
            self.send_response(code)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def _wants_prom(self, query: str) -> bool:
            """Content negotiation for /metrics: explicit ``?format=prom``
            wins; otherwise an Accept header naming the text exposition
            (Prometheus scrapers send ``text/plain;version=0.0.4`` or an
            openmetrics type — a browser's ``text/html,...`` does not
            match)."""
            if "format=prom" in query:
                return True
            accept = self.headers.get("Accept", "")
            return "openmetrics" in accept or "text/plain" in accept

        def do_GET(self):
            path, _, query = self.path.partition("?")
            if path == "/health":
                self._json(200, {"status": "ok",
                                 "workers": len(system.workers),
                                 "models": [c.name for c in system.cfgs]})
            elif path == "/allocation":
                self._json(200, {"models": system.alloc.model_names,
                                 "A": system.alloc.A.tolist()})
            elif path == "/v2/trace":
                # flight-recorder export (DESIGN.md §13): the live Perfetto
                # timeline, or the anomaly-triggered dumps with ?dumps=1
                if "dumps=1" in query:
                    self._json(200, {"dumps": system.tracer.dumps(),
                                     "anomalies": system.tracer.anomalies()})
                else:
                    self._json(200, system.tracer.export())
            elif path == "/metrics":
                if self._wants_prom(query):
                    system.serving_gauges()   # refresh worker health gauges
                    self._text(200, prometheus_text(system.timers),
                               PROM_CONTENT_TYPE)
                    return
                ctl = system.controller
                self._json(200, {
                    "counters": system.serving_counters(),
                    "gauges": system.serving_gauges(),
                    # per-class p50/p99 (incl. hp_p50 — the SLO the
                    # chunk-granular preemption targets, DESIGN.md §3)
                    "latency": system.latency_snapshot(),
                    # raw log-scale buckets behind those percentiles (§13)
                    "latency_hist": system.timers.latency_histogram(),
                    "stages": system.stage_timings(),
                    "cache": ({"hits": cache.hits, "misses": cache.misses}
                              if cache is not None else None),
                    # online reconfiguration observability (DESIGN.md §8)
                    "controller": ctl.stats() if ctl is not None else None,
                    # overload/brownout observability (DESIGN.md §11)
                    "brownout": (system.brownout.stats()
                                 if system.brownout is not None else None),
                    "admission_budget": (
                        system.admission_budget.snapshot()
                        if system.admission_budget is not None else None)})
            else:
                self._json(404, {"error": "not found"})

        def _tokens(self, payload) -> np.ndarray:
            x = np.asarray(payload["tokens"], np.int32)
            if x.ndim != 2:
                raise ValueError("tokens must be 2-D (batch, seq)")
            return x

        def do_POST(self):
            try:
                length = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(length))
                if self.path == "/v2/predict":
                    x = self._tokens(payload)
                    opts = _parse_options(payload)
                    quality = 1.0
                    try:
                        h = client.predict_async(x, opts)
                        y = h.result(600.0)
                        quality = h.quality()
                    except DeadlineExceeded as e:
                        self._json(504, {"error": f"deadline exceeded: {e}"})
                        return
                    except Overloaded as e:
                        # refused at admission (DESIGN.md §11): infeasible
                        # deadline or exhausted byte/row budget — 429, with
                        # Retry-After computed from the drain estimate
                        ra = self._retry_after(e)
                        self._json(429,
                                   {"error": f"{type(e).__name__}: {e}",
                                    "retry_after_s": ra},
                                   headers={"Retry-After": _header_s(ra)})
                        return
                    except ServingUnavailable as e:
                        # transient capacity failure (quarantined member /
                        # exhausted retries, DESIGN.md §10): retryable —
                        # 503 + Retry-After, never a permanent error.  Same
                        # drain-estimate-derived value as the 429 path
                        ra = self._retry_after(e)
                        self._json(503,
                                   {"error": f"{type(e).__name__}: {e}",
                                    "retry_after_s": ra},
                                   headers={"Retry-After": _header_s(ra)})
                        return
                    if y is None:
                        self._json(500, {"error": "prediction failed"})
                        return
                    out = {"predictions": y.tolist()}
                    if quality < 1.0:     # degraded partial-ensemble result
                        out["quality"] = quality
                    self._json(200, out)
                    return
                elif self.path == "/predict":   # v1 compatibility shim
                    x = self._tokens(payload)
                    y = batcher.submit(x)
                else:
                    self._json(404, {"error": "not found"})
                    return
                if y is None:
                    self._json(500, {"error": "prediction failed"})
                    return
                self._json(200, {"predictions": y.tolist()})
            except (KeyError, TypeError, ValueError,
                    json.JSONDecodeError) as e:
                self._json(400, {"error": str(e)})

    httpd = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    return httpd, batcher
