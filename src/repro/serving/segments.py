"""Segment protocol (paper §II.C.1).

Requests are split into fixed-size segments; only small integer segment ids
flow through the FIFO queues while the sample bytes live in the shared X
buffer.  Special ids: ``SHUTDOWN`` asks a worker to exit; workers emit
``Message(OOM/READY, ...)`` sentinels to the prediction accumulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

SHUTDOWN = -1          # segment-ids-queue sentinel: worker must exit
OOM = -1               # prediction-queue sentinel: device out of memory
READY = -2             # prediction-queue sentinel: worker initialized

DEFAULT_SEGMENT_SIZE = 128      # paper §III: fixed to 128


def num_segments(nb_samples: int, segment_size: int) -> int:
    return (nb_samples + segment_size - 1) // segment_size


def start(s: int, segment_size: int) -> int:
    return s * segment_size


def end(s: int, segment_size: int, nb_samples: int) -> int:
    return min((s + 1) * segment_size, nb_samples)


@dataclass
class Message:
    """The {s, m, P} triplet (paper §II.C.2).  Sentinels use P=None."""
    s: int                       # segment id (or OOM / READY)
    m: Optional[int]             # model id
    P: Optional[np.ndarray]      # (end(s)-start(s), C) prediction matrix

    @property
    def is_sentinel(self) -> bool:
        return self.s < 0
