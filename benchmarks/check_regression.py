"""CI gate: compare a fresh ``BENCH_serving.json`` against the committed
baseline (``benchmarks/BENCH_baseline.json``) and fail on regression.

    PYTHONPATH=src python -m benchmarks.check_regression BENCH_serving.json \
        [--baseline benchmarks/BENCH_baseline.json] [--tolerance 0.30] \
        [--select serving.] [--select sim.]

Only machine-independent *relative* metrics are gated (speedups, ratios,
padding efficiency) — absolute segments/sec varies with the runner's
hardware, but the engine-vs-engine ratios measured on one box should hold on
another.  A metric fails when ``current < baseline * (1 - tolerance)``.

Every gated metric is evaluated (a miss never hides the metrics after it)
and the result is one per-metric pass/fail table; a metric absent from
either file reports MISS instead of crashing the gate, and still fails it.
``--select PREFIX`` (repeatable) restricts the gate to metrics whose dotted
path starts with a prefix, so a CI job that only ran a subset of the bench
(e.g. serving-smoke runs the serving scenarios minus `sim_fidelity`, which
the sim-smoke job owns) gates exactly what it measured instead of MISSing
the rest.
"""
from __future__ import annotations

import argparse
import json
import sys

# dotted paths from the root of BENCH_serving.json as
# (metric, relative_tolerance, absolute_floor).  relative_tolerance None ->
# the global --tolerance; the effective floor is max(relative, absolute).
# large_request_ratio enforces the documented acceptance bound — coalescing
# within 5% of the PR-1 engine on single large requests — as an absolute
# floor of 0.90 (5% criterion + 5% allowance for shared-runner noise)
# rather than a tolerance on the committed ~1.0 baseline.
# mixed_priority gates the ISSUE-3 acceptance: high-priority p99 >= 3x
# better than strict FIFO (absolute floor; the wide relative tolerance
# absorbs cross-runner tail-latency noise on the committed baseline) with
# total throughput bounded at 0.80x FIFO absolute (typical runs sit at
# 0.85-0.95 — sustained preemption deliberately trades a little bulk
# throughput for the ~50x high-priority p50) — plus the ISSUE-5
# acceptance: the chunk-granular dispatch queue must move the *median*,
# not just the tail (hp_p50_improvement >= 4x; queue-level priority alone
# leaves p50 stuck behind already-flushed bulk slots).
# skewed_load gates the ISSUE-4 acceptance: work stealing >= 1.3x throughput
# under a 4:1 per-member load skew (absolute floor; the scenario runs on
# simulated device time, so it is deterministic across runners).
# fault_recovery gates the ISSUE-6 acceptance: killing one data-parallel
# sibling mid-trace loses zero requests (completed_ratio == 1.0 at full
# quality — replay, not degradation) and crash-to-replay recovery lands
# within a second (recovery_ok folds that bound with exactly one
# quarantine); raw recovery_s is reported in BENCH_serving.json ungated.
# overload_brownout gates the ISSUE-7 acceptance: at 3x saturation every
# request either completes with a quality-stamped result or is shed with a
# typed Overloaded (completed_or_shed_ratio == 1.0 — nothing hangs, nothing
# dies untyped) and the brownout controller improves normal-class p99 >= 2x
# over the uncontrolled run (absolute floor; the scenario runs on simulated
# device time, the wide relative tolerance absorbs the committed baseline's
# much larger measured headroom).
# tracing_overhead gates the ISSUE-9 acceptance: the span layer plus
# flight recorder must cost <= 5% on the fake-worker hot path.  Gates
# here are lower-bound only (cur >= floor), so the <= 1.05 budget is
# encoded as the derived boolean ``overhead_ok = ratio <= 1.05``
# computed by the bench itself, gated at an absolute floor of 1.0;
# the raw overhead_ratio is reported in BENCH_serving.json ungated.
# serving.sim_fidelity + the sim.* block gate the ISSUE-8 acceptance:
# the calibrated simulator reproduces a real fake-device run's throughput
# and p99 within 20% (fidelity_ok folds both ratios), a 1M-request trace
# replays in < 60 s single-process with bit-identical reruns (scale_ok /
# determinism_ok; replay_req_per_s carries a wide 0.5 tolerance — replay
# speed IS machine-dependent, but a 2x collapse means a sim hot-path
# regression), forecast-fed replanning beats EWMA-fed on the diurnal trace
# by >= 1.2x p99 (deterministic; typical 1.5x), the dispatch-ahead tuner
# reproduces the live K=16 default, and the EDF prototype eliminates
# >= 90% of FIFO's deadline misses on the burst trace (deterministic 100%).
GATED_METRICS = [
    ("serving.speedup", None, None),          # pipelined engine vs seed
    ("serving.large_request_ratio", None, 0.90),  # coalesced vs PR-1, 1 big
    ("serving.many_small.speedup", None, None),   # coalesced vs PR-1, small
    ("serving.many_small.coalesced.padding_efficiency", 0.15, None),
    # latency-ratio metrics carry wide relative tolerances: tail percentiles
    # on shared runners are volatile, and the absolute floors are what the
    # acceptance criteria pin (p50 >= 4x, p99 >= 3x)
    ("serving.mixed_priority.hp_p50_improvement", 0.85, 4.0),
    ("serving.mixed_priority.hp_p99_improvement", 0.85, 3.0),
    # sustained preemption deliberately trades a little bulk throughput for
    # the ~50x high-priority p50: 0.80 bounds that trade; typical runs sit
    # at 0.85-0.95
    ("serving.mixed_priority.throughput_ratio", None, 0.80),
    ("serving.skewed_load.steal_throughput_ratio", None, 1.30),
    ("serving.fault_recovery.completed_ratio", 0.0, 1.0),
    ("serving.fault_recovery.recovery_ok", 0.0, 1.0),
    ("serving.overload_brownout.completed_or_shed_ratio", 0.0, 1.0),
    ("serving.overload_brownout.brownout_p99_improvement", 0.85, 2.0),
    ("serving.tracing_overhead.overhead_ok", 0.0, 1.0),
    # quantized members (ISSUE 10): int8 must buy >= 1.3x segments/sec on
    # the heavy-member scenario, and the fused dequant-combine epilogue must
    # match the fp32 reference within int8 tolerance (full ensemble AND a
    # member subset — a binary verdict, no drift tolerance)
    ("serving.quantized_members.quant_speedup", None, 1.30),
    ("serving.quantized_members.quant_parity_ok", 0.0, 1.0),
    ("serving.sim_fidelity.fidelity_ok", 0.0, 1.0),
    ("sim.scale.scale_ok", 0.0, 1.0),
    ("sim.scale.determinism_ok", 0.0, 1.0),
    ("sim.scale.replay_req_per_s", 0.5, None),
    ("sim.forecast_replan.p99_improvement", 0.85, 1.20),
    ("sim.ktuner.recommended_ok", 0.0, 1.0),
    ("sim.edf.miss_reduction", 0.15, 0.90),
]


def lookup(d: dict, dotted: str):
    for part in dotted.split("."):
        d = d[part]
    return float(d)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="fresh BENCH_serving.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="PREFIX",
                    help="gate only metrics whose dotted path starts with "
                         "PREFIX (repeatable); default: all gated metrics")
    args = ap.parse_args()

    with open(args.results) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    gated = GATED_METRICS
    if args.select:
        gated = [g for g in GATED_METRICS
                 if any(g[0].startswith(p) for p in args.select)]
        if not gated:
            print(f"--select matched no gated metrics: {args.select}",
                  file=sys.stderr)
            return 1

    width = max(len(m) for m, _, _ in gated)
    rows, failures = [], []
    for metric, tol, abs_floor in gated:
        tol = args.tolerance if tol is None else tol
        try:
            base = lookup(baseline, metric)
            cur = lookup(current, metric)
        except (KeyError, TypeError, ValueError):   # absent or non-numeric:
            rows.append((metric, "MISS", "-", "-", "-"))    # report, fail,
            failures.append(metric)                         # keep going
            continue
        floor = base * (1.0 - tol)
        if abs_floor is not None:
            floor = max(floor, abs_floor)
        ok = cur >= floor
        rows.append((metric, "OK" if ok else "FAIL",
                     f"{cur:.3f}", f"{base:.3f}", f"{floor:.3f}"))
        if not ok:
            failures.append(metric)

    print(f"{'metric':<{width}}  {'status':<6} {'current':>8} "
          f"{'baseline':>8} {'floor':>8}")
    for metric, status, cur, base, floor in rows:
        print(f"{metric:<{width}}  {status:<6} {cur:>8} {base:>8} {floor:>8}")

    if failures:
        print(f"regression in: {', '.join(failures)}", file=sys.stderr)
        return 1
    print("no regression beyond tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
