"""Deterministic fault injection for the serving pipeline (DESIGN.md §10).

Every recovery path in the fault-tolerance layer — supervision, quarantine,
chunk replay, graceful degradation — must be testable without real hardware
failures.  A :class:`FaultPlan` is a set of :class:`FaultSpec` triggers the
worker consults at fixed instrumentation points; on ``fake_delay_us``
simulated devices the Nth-chunk counters make the failure land at the same
pipeline position every run:

  * ``stage="batcher"``    fires on the Nth admitted (request, segment)
                           descriptor (after its in-flight ledger entry is
                           registered, so recovery is exercised, not a
                           pre-admission drop);
  * ``stage="predictor"``  fires on the Nth committed chunk, before its
                           dispatch — ``kind="nan"`` substitutes a NaN
                           output matrix instead (caught by the sender's
                           ``nan_guard``);
  * ``stage="sender"``     fires on the Nth chunk entering materialization,
                           before any contribution is forwarded (so the
                           ledger pop-gate, not luck, decides idempotency);
  * ``stage="spawn"``      fires in ``Worker.__init__`` — a failed spawn,
                           exercising the controller's backoff path.

Kinds: ``raise`` (the stage thread dies with :class:`InjectedFault`),
``stall`` (the stage sleeps ``stall_s`` — past the supervisor watchdog the
worker is quarantined while the thread is still alive, exercising the
late-wakeup idempotency protocol), ``nan`` (predictor only), ``slow``
(the stage sleeps ``stall_s`` but the spec stays armed with ``repeat=True``
— sustained slowdown, the overload/brownout drill in DESIGN.md §11, as
opposed to ``stall``'s one-shot hang).

Each spec fires **once** unless ``repeat=True``; counters are per
(worker, stage), so one plan can be shared by a whole system and scoped
with ``worker=`` prefixes.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

_STAGES = ("batcher", "predictor", "sender", "spawn")
_KINDS = ("raise", "stall", "nan", "slow")


class InjectedFault(RuntimeError):
    """A deterministic test fault raised by a :class:`FaultPlan` trigger."""


@dataclass
class FaultSpec:
    """One trigger: in ``stage``, on the ``after``+1-th unit, do ``kind``.

    ``worker`` scopes the spec to worker ids starting with that prefix
    (``"w0.1"`` matches the generation-tagged respawns too); None = any."""
    stage: str
    kind: str = "raise"
    after: int = 0              # units through the stage before firing
    stall_s: float = 30.0       # kind="stall"/"slow": simulated hang/delay
    worker: Optional[str] = None
    repeat: bool = False        # stay armed after firing (sustained faults)

    def __post_init__(self):
        if self.stage not in _STAGES:
            raise ValueError(f"unknown fault stage {self.stage!r} "
                             f"(expected one of {_STAGES})")
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {_KINDS})")
        if self.kind == "nan" and self.stage != "predictor":
            raise ValueError("kind='nan' only applies to stage='predictor'")
        if self.kind == "slow":
            self.repeat = True  # a one-shot "slow" is just a short stall

    def matches(self, worker_id: str) -> bool:
        return self.worker is None or worker_id.startswith(self.worker)

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Build a spec from a ``key=value[,key=value...]`` CLI string, e.g.
        ``stage=predictor,kind=raise,after=3,worker=w0.0``."""
        kw: Dict[str, object] = {}
        for part in text.split(","):
            if not part.strip():
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key in ("after",):
                kw[key] = int(val)
            elif key in ("stall_s",):
                kw[key] = float(val)
            elif key in ("repeat",):
                kw[key] = val.strip().lower() in ("1", "true", "yes")
            elif key in ("stage", "kind", "worker"):
                kw[key] = val.strip()
            else:
                raise ValueError(f"unknown --fault key {key!r}")
        if "stage" not in kw:
            raise ValueError("--fault needs at least stage=<name>")
        return cls(**kw)  # type: ignore[arg-type]


class FaultPlan:
    """A shared, thread-safe set of triggers.  ``tick`` is the worker-side
    hook: it counts one unit through ``stage`` for ``worker_id`` and fires
    any matching armed spec — raising for ``raise``, sleeping for ``stall``
    (the sleep releases the GIL, so the supervisor keeps running), and
    returning ``"nan"`` for ``nan`` so the predictor substitutes outputs.
    Workers skip the call entirely when no plan is configured, so the hot
    path pays nothing by default."""

    def __init__(self, *specs: FaultSpec):
        self._specs: List[FaultSpec] = list(specs)
        self._armed: List[bool] = [True] * len(self._specs)
        self._counts: Dict[Tuple[str, str], int] = {}
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, str, str]] = []   # (worker, stage, kind)

    def add(self, spec: FaultSpec) -> "FaultPlan":
        with self._lock:
            self._specs.append(spec)
            self._armed.append(True)
        return self

    def tick(self, worker_id: str, stage: str) -> Optional[str]:
        with self._lock:
            key = (worker_id, stage)
            n = self._counts.get(key, 0)
            self._counts[key] = n + 1
            hit = None
            for i, spec in enumerate(self._specs):
                if (self._armed[i] and spec.stage == stage
                        and spec.matches(worker_id) and n >= spec.after):
                    if not spec.repeat:
                        self._armed[i] = False
                    self.fired.append((worker_id, stage, spec.kind))
                    hit = spec
                    break
        if hit is None:
            return None
        if hit.kind in ("stall", "slow"):
            time.sleep(hit.stall_s)
            return None
        if hit.kind == "nan":
            return "nan"
        raise InjectedFault(
            f"injected {stage} fault on {worker_id} (unit {n})")
