"""HTTP wrapper tests: the v2 request API (per-request options, /metrics)
plus the v1 /predict adaptive-batching compatibility shim."""
import json
import threading
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.client import EnsembleClient
from repro.serving.request_cache import PredictionCache
from repro.serving.segments import DeadlineExceeded, PredictOptions
from repro.serving.server import serve
from repro.serving.system import InferenceSystem

PORT = 8691
SEQ = 16


@pytest.fixture(scope="module")
def server():
    cfgs = ensemble("ENS4")[:1]
    params = [M.init_params(jax.random.PRNGKey(0), cfgs[0])]
    devs = host_cpus(1, memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [cfgs[0].name], np.array([[8]]))
    system = InferenceSystem(cfgs, params, alloc, segment_size=16, max_seq=SEQ)
    httpd, batcher = serve(system, port=PORT, max_wait_s=0.02)
    yield system
    httpd.shutdown()
    batcher.stop()
    system.shutdown()


def _get(path):
    return json.load(urllib.request.urlopen(f"http://127.0.0.1:{PORT}{path}"))


def _post(path, payload):
    req = urllib.request.Request(
        f"http://127.0.0.1:{PORT}{path}", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    return json.load(urllib.request.urlopen(req))


def test_health(server):
    r = _get("/health")
    assert r["status"] == "ok" and r["workers"] == 1


def test_allocation_endpoint(server):
    r = _get("/allocation")
    assert r["A"] == [[8]]


def test_predict_roundtrip(server):
    x = np.random.default_rng(0).integers(0, 512, (3, SEQ)).tolist()
    r = _post("/predict", {"tokens": x})
    y = np.asarray(r["predictions"])
    assert y.shape == (3, 512)
    assert np.isfinite(y).all()


def test_bad_request(server):
    req = urllib.request.Request(
        f"http://127.0.0.1:{PORT}/predict", data=b'{"tokens": [1,2,3]}',
        headers={"Content-Type": "application/json"})
    try:
        urllib.request.urlopen(req)
        assert False, "should have errored"
    except urllib.error.HTTPError as e:
        assert e.code == 400


def test_adaptive_batching_coalesces(server):
    """Concurrent small requests are served within one segment flush."""
    results = {}

    def call(i):
        x = np.random.default_rng(i).integers(0, 512, (2, SEQ)).tolist()
        results[i] = np.asarray(_post("/predict", {"tokens": x})["predictions"])

    threads = [threading.Thread(target=call, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert len(results) == 4
    for y in results.values():
        assert y.shape == (2, 512)


# ---- the v2 request API ------------------------------------------------------

def test_v2_predict_roundtrip(server):
    x = np.random.default_rng(20).integers(0, 512, (3, SEQ)).tolist()
    r = _post("/v2/predict", {"tokens": x, "priority": "high",
                              "members": [0], "deadline_ms": 60_000})
    y = np.asarray(r["predictions"])
    assert y.shape == (3, 512) and np.isfinite(y).all()
    # v1 and v2 agree on the same input
    r1 = _post("/predict", {"tokens": x})
    np.testing.assert_allclose(y, np.asarray(r1["predictions"]), atol=1e-5)


def test_v2_deadline_exceeded_is_504(server):
    x = np.zeros((2, SEQ), np.int32).tolist()
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post("/v2/predict", {"tokens": x, "deadline_ms": 1e-4})
    assert ei.value.code == 504


def test_v2_bad_options_are_400(server):
    x = np.zeros((1, SEQ), np.int32).tolist()
    for bad in ({"priority": "urgent"}, {"combine": "median"},
                {"members": [7]}, {"cache": "maybe"},
                {"priority": 1.5}, {"members": 7}):   # wrong-typed -> 400 too
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post("/v2/predict", {"tokens": x, **bad})
        assert ei.value.code == 400, bad
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post("/v2/predict", {"tokens": None})
    assert ei.value.code == 400


def test_metrics_endpoint(server):
    x = np.random.default_rng(21).integers(0, 512, (4, SEQ)).tolist()
    _post("/v2/predict", {"tokens": x})
    m = _get("/metrics")
    assert 0 < m["counters"]["padding_efficiency"] <= 1.0
    assert m["counters"]["rows_valid"] > 0
    assert any(k.startswith("queue_depth.") for k in m["gauges"])
    assert "accumulate" in m["stages"]
    # per-class latency percentiles (the hp_p50 SLO view, DESIGN.md §3/§6)
    assert m["latency"]["normal"]["p50_ms"] > 0


def test_http_client_facade(server):
    """EnsembleClient over the HTTP transport: sync, async, options, and a
    client-side cache; metrics() proxies GET /metrics."""
    client = EnsembleClient(url=f"http://127.0.0.1:{PORT}",
                            cache=PredictionCache(capacity=64))
    X = np.random.default_rng(22).integers(0, 512, (3, SEQ)).astype(np.int32)
    y1 = client.predict(X, PredictOptions(priority="high"))
    assert y1.shape == (3, 512)
    h = client.predict_async(X)                 # all rows now cached
    np.testing.assert_allclose(h.result(60.0), y1, atol=1e-6)
    assert client.cache.hits == 3
    with pytest.raises(DeadlineExceeded):
        client.predict(X, PredictOptions(deadline_ms=1e-4, cache="bypass"))
    assert client.metrics()["counters"]["rows_valid"] > 0
    with pytest.raises(ValueError, match="in-process"):
        client.predict_stream(X, lambda *a: None)
