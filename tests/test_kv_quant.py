"""int8 KV-cache quantization (§Perf beyond-paper iteration): quantized
prefill+decode tracks the f32 path within int8 tolerance for every
attention-bearing architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_config
from repro.kernels.quant import dequantize_kv, quantize_kv


def test_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 2, 64))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (4, 32, 2, 1)
    err = float(jnp.abs(dequantize_kv(q, s) - x).max())
    scale = float(jnp.abs(x).max())
    assert err < scale / 100          # ~1/127 relative


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "llama-3.2-vision-11b", "hymba-1.5b"])
def test_quantized_decode_tracks_f32(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    B, S, extra = 2, 24, 3
    tokens = jax.random.randint(rng, (B, S + extra), 0, cfg.vocab_size)
    fe = (jnp.ones((B, cfg.frontend_tokens, cfg.fdim)) * 0.1
          if cfg.frontend_tokens else None)

    lg_f, cache_f = M.prefill(params, cfg, tokens[:, :S], 64, fe)
    lg_q, cache_q = M.prefill(params, cfg, tokens[:, :S], 64, fe,
                              quantize_cache=True)
    # quantized entries present for attention layers
    assert any("k_scale" in e for e in cache_q["layers"])
    scale = float(jnp.abs(lg_f).max())
    assert float(jnp.abs(lg_q - lg_f).max()) < 0.05 * max(scale, 1.0)

    for t in range(extra):
        tok = tokens[:, S + t:S + t + 1]
        lg_f, cache_f = M.decode_step(params, cfg, cache_f, tok, jnp.int32(S + t))
        lg_q, cache_q = M.decode_step(params, cfg, cache_q, tok, jnp.int32(S + t))
        err = float(jnp.abs(lg_q - lg_f).max())
        assert err < 0.05 * max(scale, 1.0), (arch, t, err)
    # cache stays int8 across steps
    for e in cache_q["layers"]:
        if "k" in e:
            assert e["k"].dtype == jnp.int8


def test_quantized_cache_halves_bytes():
    cfg = get_config("qwen3-1.7b")
    f32b = cfg.kv_cache_bytes(128, 32768, 2)          # bf16 cache
    from repro.models.cache import layer_cache_struct
    q = layer_cache_struct(cfg, "attn", 128, 32768, quantized=True)
    qbytes = sum(np.prod(sh) * (1 if dt == jnp.int8 else 4)
                 for sh, dt in q.values()) * cfg.num_layers
    assert qbytes < 0.6 * f32b
