"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh),
derived from the dry-run artifacts in experiments/dryrun/.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from the scan-unrolled lowering's cost analysis
(global totals — XLA counts a while body once, so the dry-run re-lowers with
scans unrolled; see dryrun.py).  Collective bytes come from the compiled
SPMD executable's HLO with while-body trip-count scaling
(hlo_analysis.collective_bytes); shapes there are per-device shards, and
all-reduce is weighted 2x (reduce-scatter + all-gather on the wire).

Usage:
    python -m repro.launch.roofline                  # report over all JSONs
    python -m repro.launch.roofline --mesh single --markdown
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

# wire-traffic weight per collective type (ring algorithms, large N)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D (train) / 2*N_active*D + attention (serve)."""
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    if kind == "train":
        return 3.0 * cfg.flops_per_token(s) * b * s      # fwd+bwd = 3x fwd
    if kind == "prefill":
        return float(cfg.flops_per_token(s)) * b * s
    return float(cfg.flops_per_token(s)) * b             # decode: 1 tok/sample


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    note: str
    variant: str = "baseline"

    def as_dict(self):
        return self.__dict__.copy()


def analyze_record(rec: dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    gflops = rec.get("global_cost", {}).get("flops", 0.0)
    gbytes = rec.get("global_cost", {}).get("bytes_accessed", 0.0)
    compute_s = gflops / (chips * PEAK_FLOPS)
    memory_s = gbytes / (chips * HBM_BW)
    coll = rec.get("collectives", {}).get("bytes", {})
    wire = sum(v * _WIRE_FACTOR.get(k, 1.0) for k, v in coll.items())
    collective_s = wire / LINK_BW          # bytes already per-chip shards
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / gflops if gflops else 0.0
    note = _note(rec, dominant, ratio)
    return RooflineRow(rec["arch"], rec["shape"], rec["mesh"], chips,
                       compute_s, memory_s, collective_s, dominant, mf,
                       gflops, ratio, note,
                       variant=rec.get("variant", "baseline"))


def _note(rec: dict, dominant: str, ratio: float) -> str:
    coll = rec.get("collectives", {}).get("bytes", {})
    biggest_coll = max(coll, key=coll.get) if coll else "none"
    if dominant == "collective":
        return (f"dominated by {biggest_coll}; reshard to cut it "
                f"(e.g. keep activations model-sharded through the stack)")
    if dominant == "memory":
        if rec["shape"].startswith(("decode", "long")):
            return ("KV/state streaming bound; fuse cache read+attend "
                    "(decode kernel) or quantize cache to int8")
        return "activation traffic bound; increase fusion / remat less"
    if ratio < 0.5:
        return ("compute-bound but HLO does >2x model FLOPs; cut remat "
                "recompute or f32 upcasts")
    return "compute-bound near useful-FLOPs roofline; scale batch or chips"


def load_rows(mesh: Optional[str] = None, variant: str = "baseline"
              ) -> List[RooflineRow]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(f))
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("variant", "baseline") != variant:
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | note |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.note} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = load_rows(args.mesh, args.variant)
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(f"{r.arch:24s} {r.shape:12s} {r.mesh:6s} "
                  f"C={r.compute_s:.2e} M={r.memory_s:.2e} "
                  f"X={r.collective_s:.2e} -> {r.dominant:10s} "
                  f"useful={r.useful_ratio:.2f}")
    if args.json_out:
        json.dump([r.as_dict() for r in rows], open(args.json_out, "w"),
                  indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
