"""Per-member chunk service-time models.

The simulator's only free parameters: how long a (member, bucket) chunk
occupies its worker's predictor, plus a fixed per-dispatch-group overhead
(the pop/ship cost the dispatch-ahead window K amortizes).

Two fit paths:

* :meth:`ServiceModel.from_delays` — from known ``fake_delay_us`` settings
  (the fake predictor sleeps a fixed time per chunk regardless of bucket,
  so the model is bucket-flat).
* :meth:`ServiceModel.from_livebench` — from the ``latency_ewma_s`` block
  of a :class:`~repro.serving.control.livebench.LiveBench` snapshot taken
  during a real (simulated-device) run: keys ``m{m}|{dev}|b{bucket}``.
  This is the calibration path the `sim_fidelity` bench gate exercises —
  record a trace + profile from a live run, fit, replay, compare.
  Measured EWMAs already embed dispatch overhead, so fitted models default
  to ``dispatch_overhead_s=0``.

Unknown buckets are priced by nearest-bucket scaling with the same
``OVERHEAD_FLOOR`` rule LiveBench itself uses, so sim and live planner
agree on extrapolated costs.
"""
from __future__ import annotations

import re
from typing import Dict, Mapping, Optional, Tuple

from repro.serving.control.livebench import OVERHEAD_FLOOR

__all__ = ["ServiceModel"]

_KEY_RE = re.compile(r"^m(\d+)\|(.+)\|b(\d+)$")


class ServiceModel:
    """Chunk service time in seconds, keyed ``(member, bucket)``."""

    def __init__(self, latency_s: Mapping[Tuple[int, int], float],
                 *, default_s: float = 1e-3,
                 dispatch_overhead_s: float = 0.0):
        self._lat: Dict[Tuple[int, int], float] = {
            (int(m), int(b)): float(s) for (m, b), s in latency_s.items()}
        self._buckets: Dict[int, Tuple[int, ...]] = {}
        for (m, b) in self._lat:
            self._buckets.setdefault(m, ())
        for m in self._buckets:
            self._buckets[m] = tuple(sorted(
                b for (mm, b) in self._lat if mm == m))
        self.default_s = float(default_s)
        self.dispatch_overhead_s = float(dispatch_overhead_s)

    @classmethod
    def from_delays(cls, delays_us: Mapping[int, float], *,
                    dispatch_overhead_s: float = 0.0) -> "ServiceModel":
        """Bucket-flat model from per-member ``fake_delay_us`` settings."""
        lat = {(int(m), 0): float(us) * 1e-6 for m, us in delays_us.items()}
        return cls(lat, dispatch_overhead_s=dispatch_overhead_s)

    @classmethod
    def from_livebench(cls, snapshot: Mapping, *,
                       dispatch_overhead_s: float = 0.0) -> "ServiceModel":
        """Fit from ``LiveBench.snapshot()`` (or the raw ``latency_ewma_s``
        mapping).  Multiple device keys for the same (member, bucket) are
        averaged — the sim routes by member, not device identity."""
        ewma = snapshot.get("latency_ewma_s", snapshot)
        acc: Dict[Tuple[int, int], Tuple[float, int]] = {}
        for key, s in ewma.items():
            mt = _KEY_RE.match(key)
            if not mt:
                continue
            k = (int(mt.group(1)), int(mt.group(3)))
            tot, n = acc.get(k, (0.0, 0))
            acc[k] = (tot + float(s), n + 1)
        if not acc:
            raise ValueError("no latency_ewma_s entries to fit from")
        lat = {k: tot / n for k, (tot, n) in acc.items()}
        return cls(lat, dispatch_overhead_s=dispatch_overhead_s)

    def chunk_time(self, m: int, bucket: int) -> float:
        """Service seconds for one ``bucket``-row chunk of member ``m``.
        Mirrors ``LiveBench._measured_latency``: exact hit, else nearest
        measured bucket scaled by the row ratio with an overhead floor."""
        s = self._lat.get((m, bucket))
        if s is not None:
            return s
        buckets = self._buckets.get(m)
        if not buckets:
            return self.default_s
        b = min(buckets, key=lambda bb: abs(bb - bucket))
        s = self._lat[(m, b)]
        if b <= 0:          # bucket-flat model (from_delays)
            return s
        return s * max(bucket / b, OVERHEAD_FLOOR)

    def members(self) -> Tuple[int, ...]:
        return tuple(sorted(self._buckets))

    def fake_delay_us(self, m: int, batch: int) -> float:
        """Equivalent ``fake_delay_us`` for a full-batch chunk — lets the
        real control plane (``estimate_drain_s``, brownout member costs)
        price sim workers exactly as it prices fake-device workers."""
        return self.chunk_time(m, batch) * 1e6
