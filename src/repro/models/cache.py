"""Decode-state pytrees: KV ring buffers, SSM states, cross-attn KV.

Cache layout mirrors the parameter layout: ``cache["layers"]`` is a list with
one entry per pattern-unit position; every leaf carries a leading ``repeats``
dimension so the layer stack can ``lax.scan`` over it.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.configs.base import ATTN, CROSS, HYBRID, SSM, SWA, ModelConfig


def quantize_kv(x):
    """Per-(…, head) symmetric int8 quantization along head_dim.

    x: (..., hd) -> (q int8 (..., hd), scale f32 (..., 1)).  Beyond-paper
    §Perf iteration: halves decode KV-streaming bytes (the dominant roofline
    term for decode shapes) at ~1e-2 relative attention error."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def layer_cache_struct(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                       dtype=jnp.float32, *, quantized: bool = False
                       ) -> Dict[str, Any]:
    """Shapes (without the leading repeats dim) of one layer's cache."""
    out: Dict[str, Any] = {}
    kv, hd = cfg.num_kv_heads, cfg.hd

    def kv_entry(L):
        if quantized:
            out["k"] = ((batch, L, kv, hd), jnp.int8)
            out["v"] = ((batch, L, kv, hd), jnp.int8)
            out["k_scale"] = ((batch, L, kv, 1), jnp.float32)
            out["v_scale"] = ((batch, L, kv, 1), jnp.float32)
        else:
            out["k"] = ((batch, L, kv, hd), dtype)
            out["v"] = ((batch, L, kv, hd), dtype)

    if kind in (ATTN, SWA, HYBRID):
        kv_entry(max_len if kind == ATTN else min(max_len, cfg.sliding_window))
    if kind == CROSS:
        kv_entry(cfg.frontend_tokens)
    if kind in (SSM, HYBRID):
        s = cfg.ssm
        out["h"] = ((batch, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32)
        out["conv"] = ((batch, s.d_conv - 1, cfg.d_inner + 2 * s.d_state), dtype)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
               *, quantized: bool = False):
    """Zero-initialized cache pytree."""
    layers = []
    for kind in cfg.pattern:
        entry = {}
        for name, (shape, dt) in layer_cache_struct(
                cfg, kind, batch, max_len, dtype, quantized=quantized).items():
            entry[name] = jnp.zeros((cfg.repeats,) + shape, dt)
        layers.append(entry)
    return {"layers": layers}


def cache_struct(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
                 *, quantized: bool = False):
    """ShapeDtypeStruct version (for dry-run lowering, no allocation)."""
    import jax
    layers = []
    for kind in cfg.pattern:
        entry = {}
        for name, (shape, dt) in layer_cache_struct(
                cfg, kind, batch, max_len, dtype, quantized=quantized).items():
            entry[name] = jax.ShapeDtypeStruct((cfg.repeats,) + shape, dt)
        layers.append(entry)
    return {"layers": layers}
