"""Mamba2 SSD invariants: chunked == sequential, chunk-size independence,
decode step == full scan, hybrid block consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import ssd_scan_sequential_ref
from repro.models.ssm import ssd_chunked, ssd_final_state


def _inputs(key, b=2, s=64, h=4, p=32, n=16):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, bm, cm


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_chunk_size_invariance(chunk):
    x, dt, A, bm, cm = _inputs(0)
    y = ssd_chunked(x, dt, A, bm, cm, chunk)
    y_ref = ssd_scan_sequential_ref(x, dt, A, bm, cm)
    scale = float(jnp.abs(y_ref).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4 * scale, rtol=1e-4)


def test_final_state_matches_sequential():
    x, dt, A, bm, cm = _inputs(1)
    hfin = ssd_final_state(x, dt, A, bm, chunk=16)
    # sequential recurrence ground truth
    b, s, h, p = x.shape
    n = bm.shape[-1]
    hseq = np.zeros((b, h, p, n), np.float32)
    xn, dtn, An, bn = map(np.asarray, (x, dt, A, bm))
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None, :])
        hseq = hseq * decay[..., None, None] + \
            np.einsum("bh,bn,bhp->bhpn", dtn[:, t], bn[:, t], xn[:, t])
    np.testing.assert_allclose(np.asarray(hfin), hseq, atol=1e-3, rtol=1e-4)


def test_mamba2_decode_long_run():
    """SSM decode stays exact over many steps (state is O(1) in seq len)."""
    import repro.models as M
    cfg = get_config("mamba2-1.3b").reduced()
    rng = jax.random.PRNGKey(3)
    params = M.init_params(rng, cfg)
    total = 48
    tokens = jax.random.randint(rng, (1, total), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, tokens)
    lg, cache = M.prefill(params, cfg, tokens[:, :8], 8)
    for t in range(8, total):
        lg, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                  jnp.int32(t))
        err = float(jnp.abs(lg - logits_full[:, t]).max())
        assert err < 2e-3, (t, err)
    # cache size independent of t: state tensors only
    for entry in cache["layers"]:
        assert set(entry) == {"h", "conv"}


def test_hybrid_has_both_paths():
    cfg = get_config("hymba-1.5b").reduced()
    from repro.models.transformer import param_shapes
    unit = param_shapes(cfg)["layers"][0]
    assert "wq" in unit and "in_proj" in unit      # attention + mamba heads


def test_ssm_numerical_stability_long_seq():
    """Large dt*A decay must not produce NaN/inf over long sequences."""
    x, dt, A, bm, cm = _inputs(2, s=256)
    dt = dt * 5.0                                   # aggressive decay
    y = ssd_chunked(x, dt, A, bm, cm, 32)
    assert bool(jnp.isfinite(y).all())
