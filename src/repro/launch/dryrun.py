import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST run before any other import triggers jax initialization: the dry-run
#   builds the production meshes (256-chip pod / 512-chip 2-pod) from host
#   placeholder devices.  Everything below this line may import jax.

# Multi-pod dry-run: prove every (architecture x input-shape x mesh) lowers,
# compiles, fits, and report its cost/memory/collective profile.
#
# Usage:
#     python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
#     python -m repro.launch.dryrun --all                  # every combo, 1-pod
#     python -m repro.launch.dryrun --all --mesh multi     # 2-pod (512 chips)
#
# Outputs one JSON per combo under experiments/dryrun/.
# (No module docstring / __future__ import: the XLA_FLAGS lines above must be
#  the first statements in the file.)

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import (ARCHITECTURES, INPUT_SHAPES, get_config,
                           long_context_ok)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cost_analysis_dict(obj) -> dict:
    """Normalize {Lowered,Compiled}.cost_analysis() across jax versions —
    older releases return one dict per device in a list."""
    ca = obj.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def applicable(arch: str, shape: str) -> bool:
    cfg = get_config(arch)
    if shape == "long_500k" and not long_context_ok(cfg):
        return False        # pure full-attention archs skip 500k decode (DESIGN.md)
    return True


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if not out and ma is not None:
        out["repr"] = str(ma)
    return out


def run_one(arch: str, shape: str, mesh_kind: str = "single", *,
            save: bool = True, verbose: bool = True,
            variant: str = "baseline") -> dict:
    from repro.launch import steps as steps_mod
    t0 = time.perf_counter()
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "mesh_shape": dict(zip(mesh.axis_names,
                                  [int(mesh.shape[a]) for a in mesh.axis_names])),
           "variant": variant, "ok": False}
    from repro import runtime_flags
    runtime_flags.set_variant(variant, mesh)
    try:
        lowered, kind = steps_mod.lower_step(cfg, shape, mesh)
        rec["kind"] = kind
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)
        ca = cost_analysis_dict(compiled)
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        # scan-aware global cost: re-lower with every lax.scan unrolled (cheap
        # — no compile) because XLA cost analysis counts a while body once.
        from repro import runtime_flags
        try:
            runtime_flags.set_unroll(True)
            unrolled, _ = steps_mod.lower_step(cfg, shape, mesh)
            uca = cost_analysis_dict(unrolled)
            rec["global_cost"] = {
                "flops": float(uca.get("flops", 0.0)),
                "bytes_accessed": float(uca.get("bytes accessed", 0.0)),
            }
        finally:
            runtime_flags.set_unroll(False)
        rec["memory_analysis"] = memory_analysis_dict(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = hlo_analysis.collective_bytes(hlo)
        rec["op_histogram"] = hlo_analysis.op_histogram(hlo)
        rec["ok"] = True
        if verbose:
            print(f"[OK] {arch} x {shape} x {mesh_kind} "
                  f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
                  f"flops={rec['cost_analysis']['flops']:.3e}, "
                  f"coll={rec['collectives']['total_bytes']:.3e}B)")
    except Exception as e:   # a failure here is a sharding/system bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape} x {mesh_kind}: {rec['error']}")
    finally:
        runtime_flags.set_variant("baseline")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        tag = f"{arch}_{shape}_{mesh_kind}" + \
            (f"_{variant}" if variant != "baseline" else "")
        with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHITECTURES))
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    from repro import runtime_flags as _rf
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(_rf.VARIANTS))
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    combos = []
    archs = sorted(ARCHITECTURES) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            if applicable(a, s):
                combos.append((a, s))
            else:
                print(f"[SKIP] {a} x {s} (full-attention arch; see DESIGN.md)")

    failures = 0
    for mesh_kind in meshes:
        for a, s in combos:
            rec = run_one(a, s, mesh_kind, variant=args.variant)
            failures += 0 if rec["ok"] else 1
    print(f"\n{len(combos) * len(meshes)} combos, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
