"""Small-mesh dry-run integration tests (subprocess: needs its own
XLA_FLAGS device count before jax init).

The production 256/512-chip sweep runs via ``python -m repro.launch.dryrun``;
here every architecture lowers + compiles its train AND decode steps on an
8-device (2 data x 4 model) mesh with full-config sharding rules applied to
reduced variants — catching sharding-spec bugs quickly.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import list_architectures

ROOT = __file__.rsplit("/tests", 1)[0]


def _run(code: str, timeout=600):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


@pytest.mark.parametrize("arch", list_architectures())
def test_small_mesh_lowering(arch):
    code = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, json
        import dataclasses
        from repro.configs import get_config
        from repro.launch.steps import lower_step
        import repro.configs as C

        cfg = get_config("{arch}").reduced()
        # register a temporary shape table sized for the reduced model
        C.INPUT_SHAPES["tiny_train"] = dict(seq_len=64, global_batch=4, kind="train")
        C.INPUT_SHAPES["tiny_decode"] = dict(seq_len=64, global_batch=4, kind="decode")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for shape in ("tiny_train", "tiny_decode"):
            lowered, kind = lower_step(cfg, shape, mesh)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax: one dict per device
                ca = ca[0]
            assert ca.get("flops", 0) > 0, (shape, "no flops")
        print("OK {arch}")
    """
    assert f"OK {arch}" in _run(code)


def test_production_mesh_shapes():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import sys; sys.path.insert(0, "src")
        from repro.launch.mesh import make_production_mesh, batch_axes
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model") and m2.devices.size == 512
        assert batch_axes(m2) == ("pod", "data")
        print("OK mesh")
    """
    assert "OK mesh" in _run(code)
