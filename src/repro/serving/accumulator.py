"""The prediction accumulator (paper §II.C.2), multi-request edition.

Consumes messages from the single prediction queue and folds them into the
per-request ensemble prediction.  Two message kinds (DESIGN.md §§3-4):

  * **device partials** (``m is None``): already-weighted sums of ``count``
    member predictions, pre-combined on one device — the fold is just
    ``Y[start(s):end(s)] += P``;
  * **per-member messages** (legacy path, ``device_combine=False``): the
    paper's {s, m, P} triplet, folded under the request's combine rule —
    "mean"/"weighted" (``Y += w_m P``), "vote" (majority voting on argmax),
    or "pallas" (buffer the segment's M member predictions, then fuse the
    weighted combine in the ensemble_combine Pallas kernel, DESIGN.md §9.4).

Under the coalescing scheduler one member's segment may arrive split across
several messages (each tagged with ``row_lo``), so completion accounting
counts **rows, not messages**: a request owes ``n × len(members)``
member-rows, a per-member message debits ``len(P)`` rows, and a device
partial debits ``count × segment_rows``.  The total is invariant to how the
batcher packed the spans.  Early-forward audit (chunk-granular pipeline,
DESIGN.md §3): because nothing here assumes slot order — segments may
complete in any order, rows in any split — a sender forwarding a segment
the moment its last chunk returns (before its slot retires, possibly out
of segment order under priority reordering) needs no changes on this side;
the same row arithmetic closes.

Every message carries a request id, so any number of requests can be in
flight; each ``begin()`` returns a :class:`RequestHandle` the caller waits
on, and a completion callback lets the system recycle the request's input
buffer and open the in-flight window for the next request.

Request-API duties (DESIGN.md §7):
  * **deadlines** are enforced here as well as at admission — a message for
    an expired request fails the handle with :class:`DeadlineExceeded`
    instead of folding further rows, and a batcher that dropped a queued
    descriptor posts ``Message(DROPPED, ...)`` so the failure surfaces even
    when no rows ever arrive;
  * **cancellation**: ``RequestHandle.cancel()`` resolves the future with
    :class:`RequestCancelled` immediately and marks the request so batchers
    skip still-queued descriptors; completion is idempotent (a straggler
    message folding concurrently with ``cancel()`` cannot double-release
    the in-flight window);
  * **streaming partials**: with ``on_segment`` set, per-segment row
    accounting fires ``on_segment(s, lo, hi, Y[lo:hi])`` the moment a
    segment's ensemble rows close — however the spans were packed.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.serving import segments as seg
from repro.serving.metrics import StageTimers
from repro.serving.segments import (DeadlineExceeded, MemberUnavailable,
                                    Message, Request, RequestCancelled,
                                    RetriesExhausted)


class RequestHandle:
    """Per-request accumulation state + the client-side future."""

    def __init__(self, req: Request,
                 on_segment: Optional[Callable] = None):
        self.req = req
        self.Y = np.zeros((req.n, req.num_classes), np.float32)
        # member-rows still owed: every member predicts every row exactly once
        self.remaining = req.n * len(req.members)
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.messages = 0                     # data messages folded
        # graceful degradation (DESIGN.md §10): member-rows forgiven because
        # their member lost its last instance mid-request.  quality is the
        # fraction of member-rows actually served (1.0 = full ensemble);
        # _missing_w tracks the per-row missing combine weight so completed
        # rows renormalize over the members that did report.
        self.quality = 1.0
        self.degraded_rows = 0
        # brownout cascade (DESIGN.md §11): the system must not recycle the
        # request's input buffer at completion — a low-margin result may
        # resubmit the same rows to the escalation members
        self.keep_buffer = False
        self._missing_w: Optional[np.ndarray] = None
        self.on_segment = on_segment          # streaming-partials callback
        self._seg_buffers: Dict[int, Dict[int, np.ndarray]] = {}
        self._seg_rows: Dict[int, int] = {}   # pallas path: rows buffered
        self._finished = False                # guarded by accumulator lock
        self._canceller: Optional["PredictionAccumulator"] = None
        if on_segment is not None:            # member-rows owed per segment
            self._seg_remaining = {
                s: (req.bounds(s)[1] - req.bounds(s)[0]) * len(req.members)
                for s in range(req.num_segments())}
        else:
            self._seg_remaining = None

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError("prediction accumulator timed out")
        if self.error is not None:
            raise self.error
        return self.Y

    def cancel(self) -> bool:
        """Resolve the future with :class:`RequestCancelled` and mark the
        request so pipeline stages drop its remaining work.  Returns False
        when the request already completed (or was never registered).  Rows
        already packed into ring slots still flow through the pipeline —
        their messages are dropped as stale — but the in-flight window slot
        and combiner state are released immediately."""
        self.req.cancel_event.set()
        if self._canceller is None:
            return False
        return self._canceller.fail(
            self.req.rid, RequestCancelled(f"request {self.req.rid} cancelled"))


class PredictionAccumulator:
    def __init__(self, prediction_queue: "queue.Queue[Message]",
                 num_models: int, *, combine: str = "mean",
                 weights: Optional[np.ndarray] = None,
                 timers: Optional[StageTimers] = None,
                 on_complete: Optional[Callable[[RequestHandle], None]] = None,
                 tracer=None):
        if combine not in ("mean", "weighted", "vote", "pallas"):
            raise ValueError(f"unknown combine rule {combine!r}")
        self.q = prediction_queue
        self.M = num_models
        self.combine = combine
        self.weights = (np.asarray(weights, np.float32) if weights is not None
                        else np.full(num_models, 1.0 / num_models, np.float32))
        if combine == "mean":
            self.weights = np.full(num_models, 1.0 / num_models, np.float32)
        self.timers = timers or StageTimers()
        self.on_complete = on_complete
        self.tracer = tracer
        # ring cached once: rings are cleared in place, never replaced
        self._tr_ring = tracer.ring("accumulator") \
            if tracer is not None else None
        self.ready_count = 0
        self.oom = threading.Event()
        self.all_ready = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._requests: Dict[int, RequestHandle] = {}
        self._last: Optional[RequestHandle] = None
        self.data_messages = 0                # partials + per-member messages

    # ---- request lifecycle ----------------------------------------------------
    def begin(self, req: Request,
              on_segment: Optional[Callable] = None) -> RequestHandle:
        handle = RequestHandle(req, on_segment=on_segment)
        handle._canceller = self
        with self._lock:
            self._requests[req.rid] = handle
            self._last = handle
        if handle.remaining == 0:             # empty request
            self._finish(handle)
        return handle

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        """Legacy single-request helper: waits on the most recent begin()."""
        with self._lock:
            handle = self._last
        if handle is None:
            raise RuntimeError("no request in flight")
        return handle.result(timeout)

    def _finish(self, handle: RequestHandle,
                error: Optional[BaseException] = None) -> bool:
        # idempotent: completion can race cancel()/fail() from other threads,
        # and on_complete releases a BoundedSemaphore slot — exactly once.
        # The error is assigned under the same lock that claims the finish,
        # so a racing normal completion can't interleave with it.
        with self._lock:
            if handle._finished:
                return False
            handle._finished = True
            if error is not None:
                handle.error = error
            self._requests.pop(handle.req.rid, None)
        tr = self.tracer
        if isinstance(error, DeadlineExceeded):
            # deadline-miss rate feeds the brownout pressure signal (§11)
            self.timers.inc("deadline_misses")
            if tr is not None and tr.enabled:
                tr.instant("accumulator", "deadline_miss", rid=handle.req.rid)
                tr.note_deadline_miss()
        if error is None and handle.req.t_submit is not None:
            # per-class end-to-end latency (the hp_p50 SLO view, §7)
            lat = time.perf_counter() - handle.req.t_submit
            self.timers.latency(
                "high" if handle.req.priority == seg.PRIORITY_HIGH
                else "normal", lat)
            if tr is not None and tr.enabled:
                tr.instant("accumulator", "complete", rid=handle.req.rid,
                           args={"latency_ms": round(lat * 1e3, 3),
                                 "quality": round(handle.quality, 4)})
        elif error is not None and tr is not None and tr.enabled \
                and not isinstance(error, DeadlineExceeded):
            tr.instant("accumulator", "fail", rid=handle.req.rid,
                       args={"error": type(error).__name__})
        handle.done.set()
        if self.on_complete is not None:
            self.on_complete(handle)
        return True

    def fail(self, rid: int, error: BaseException) -> bool:
        """Resolve request ``rid`` with ``error`` (deadline expiry /
        cancellation).  Safe from any thread; returns False when the request
        already completed."""
        with self._lock:
            handle = self._requests.get(rid)
        if handle is None:
            return False
        done = self._finish(handle, error)
        if done and isinstance(error, RetriesExhausted):
            tr = self.tracer
            if tr is not None and tr.enabled:
                # freeze the flight recorder: the spans leading up to the
                # exhausted replay are exactly what a post-mortem needs
                tr.anomaly("retries_exhausted", f"request {rid}: {error}")
        return done

    # ---- the accumulation loop -------------------------------------------------
    def start(self):
        self._thread = threading.Thread(target=self._run, name="accumulator",
                                        daemon=True)
        self._thread.start()

    def stop(self):
        self.q.put(None)
        if self._thread:
            self._thread.join(10.0)

    def _run(self):
        while True:
            msg = self.q.get()
            if msg is None:
                return
            if msg.s == seg.READY:
                self.ready_count += 1
                if self.ready_count >= self._expected_ready():
                    self.all_ready.set()
                continue
            if msg.s == seg.OOM and msg.m is None and msg.P is None:
                self.oom.set()
                with self._lock:
                    pending = list(self._requests.values())
                for h in pending:
                    self._finish(h, MemoryError(
                        "a worker reported OOM ({-1, None, None})"))
                continue
            if msg.s == seg.DROPPED and msg.P is None:
                # a batcher refused to pack rows for an expired/cancelled
                # request; resolve the future (idempotent across workers)
                self._drop(msg.rid)
                continue
            if msg.P is None:
                # forgiveness message (s >= 0, P=None, m = the dead member):
                # the member's sole instance was quarantined — complete the
                # request without these rows (DESIGN.md §10)
                self._degrade(msg)
                continue
            self._accumulate(msg)

    def _drop(self, rid: int) -> None:
        with self._lock:
            handle = self._requests.get(rid)
        if handle is None:
            return
        if handle.req.cancel_event.is_set():
            self._finish(handle, RequestCancelled(
                f"request {rid} cancelled"))
        else:
            self._finish(handle, DeadlineExceeded(
                f"request {rid} missed its deadline in the admission queue"))

    def _degrade(self, msg: Message) -> None:
        """Debit a dead member's rows for one segment without folding
        anything, tracking the missing combine weight for the
        completion-time renormalization.  The ``pallas`` combine cannot
        degrade — its fused kernel waits for ALL members' staged rows — so
        the request fails with :class:`MemberUnavailable` instead."""
        with self._lock:
            handle = self._requests.get(msg.rid)
        if handle is None:                    # stale (failed/completed)
            return
        req = handle.req
        if req.combine == "pallas":
            self._finish(handle, MemberUnavailable(
                f"member {msg.m} lost its last instance and the 'pallas' "
                f"combine needs every member's rows"))
            return
        lo, hi = req.bounds(msg.s)
        rows = hi - lo
        if handle._missing_w is None:
            handle._missing_w = np.zeros(req.n, np.float32)
        handle._missing_w[lo:hi] += req.weights.get(msg.m, 0.0)
        handle.degraded_rows += rows
        handle.remaining -= rows
        if handle._seg_remaining is not None:
            left = handle._seg_remaining[msg.s] - rows
            handle._seg_remaining[msg.s] = left
            if left == 0:
                # streaming edge (documented): a degraded segment's partial
                # fires with the raw (un-renormalized) rows — the final Y
                # from result() is renormalized, the stream is best-effort
                try:
                    handle.on_segment(msg.s, lo, hi, handle.Y[lo:hi])
                except Exception as e:
                    self._finish(handle, e)
                    return
        if handle.remaining == 0:
            self._complete(handle)

    def _complete(self, handle: RequestHandle) -> None:
        """All member-rows accounted for: renormalize any degraded rows over
        the members that did report, stamp the quality, and finish."""
        if handle.degraded_rows:
            req = handle.req
            mw = handle._missing_w
            mask = mw[:req.n] > 0
            if mask.any():
                # served weights summed to (1 - missing); dividing restores
                # a proper convex combination over the surviving members.
                # A row that lost every member keeps Y=0 (0 / eps) — its
                # weight mass is gone entirely.
                denom = np.maximum(1.0 - mw[:req.n][mask], 1e-12)
                handle.Y[mask] /= denom[:, None]
            total = req.n * len(req.members)
            # multiply, don't assign: a brownout-tier request enters with
            # quality = its tier's served weight fraction (< 1.0), and
            # mid-flight degradation/demotion compounds onto it.  For the
            # common full-quality entry (1.0 * x) this is bit-identical to
            # the old assignment.
            handle.quality *= 1.0 - handle.degraded_rows / max(total, 1)
            self.timers.inc("degraded_requests")
        self._finish(handle)

    _expected_ready_count = None

    def expect_ready(self, n: int):
        self._expected_ready_count = n
        if self.ready_count >= n:
            self.all_ready.set()

    def _expected_ready(self) -> int:
        return self._expected_ready_count or 1

    def _accumulate(self, msg: Message):
        t0 = time.perf_counter()
        with self._lock:
            handle = self._requests.get(msg.rid)
        if handle is None:                    # stale (timed-out/failed request)
            return
        req = handle.req
        if req.expired():                     # deadline enforcement (§7)
            self._finish(handle, DeadlineExceeded(
                f"request {req.rid} missed its deadline mid-flight"))
            return
        lo, hi = req.bounds(msg.s)
        self.data_messages += 1
        handle.messages += 1
        if msg.m is None:
            # device partial: weights already applied on-device; the combiner
            # flushes full segments, so this debits count x segment rows
            handle.Y[lo:hi] += msg.P
            rows = msg.count * (hi - lo)
        else:
            self._fold_member(handle, msg, lo, hi)
            rows = int(msg.P.shape[0])
        handle.remaining -= rows
        if handle._seg_remaining is not None:
            left = handle._seg_remaining[msg.s] - rows
            handle._seg_remaining[msg.s] = left
            if left == 0:                     # streaming partial: segment done
                try:
                    handle.on_segment(msg.s, lo, hi, handle.Y[lo:hi])
                except Exception as e:
                    # a raising client callback fails the request (through
                    # the idempotent finish — never by assigning error
                    # outside the lock) but must not kill this loop
                    self._finish(handle, e)
                    return
        t1 = time.perf_counter()
        self.timers.add("accumulate", t1 - t0)
        tr = self.tracer
        if tr is not None and tr.enabled:
            self._tr_ring.append(
                ("X", "accumulate", t0, t1 - t0, msg.rid,
                 msg.s, rows, None))
        if handle.remaining == 0:
            self._complete(handle)

    def _fold_member(self, handle: RequestHandle, msg: Message,
                     lo: int, hi: int):
        """Fold a per-member span message: rows ``[row_lo, row_lo+len(P))``
        of segment ``s``, i.e. request rows ``[lo+row_lo, ...)``."""
        req = handle.req
        w = req.weights[msg.m]
        a = lo + msg.row_lo
        b = a + int(msg.P.shape[0])
        if req.combine in ("mean", "weighted"):
            # the paper's one-liner: Y[start:end] += P / M (weighted form)
            handle.Y[a:b] += msg.P * w
        elif req.combine == "vote":
            onehot = np.zeros_like(handle.Y[a:b])
            onehot[np.arange(b - a), msg.P.argmax(axis=1)] = w
            handle.Y[a:b] += onehot
        elif req.combine == "pallas":
            # spans buffer into per-(segment, member) staging rows; the fused
            # kernel runs once all members' rows for the segment are present.
            # Whole-segment messages (the common case — senders reassemble
            # spans) store by reference instead of paying an alloc + copy.
            buf = handle._seg_buffers.setdefault(msg.s, {})
            if msg.row_lo == 0 and msg.P.shape[0] == hi - lo:
                buf[msg.m] = msg.P
            else:
                arr = buf.get(msg.m)
                if arr is None:
                    arr = buf[msg.m] = np.zeros((hi - lo, req.num_classes),
                                                np.float32)
                arr[msg.row_lo:msg.row_lo + msg.P.shape[0]] = msg.P
            got = self._seg_rows_add(handle, msg.s, int(msg.P.shape[0]))
            if got == (hi - lo) * len(req.members):
                from repro.kernels import ops as kops
                import jax.numpy as jnp
                stacked = jnp.asarray(np.stack([buf[m] for m in req.members]))
                wv = jnp.asarray(np.array([req.weights[m] for m in req.members],
                                          np.float32))
                handle.Y[lo:hi] = np.asarray(kops.ensemble_combine(stacked, wv))
                del handle._seg_buffers[msg.s]
                del handle._seg_rows[msg.s]
        else:
            raise ValueError(f"unknown combine rule {req.combine!r}")

    @staticmethod
    def _seg_rows_add(handle: RequestHandle, s: int, rows: int) -> int:
        got = handle._seg_rows.get(s, 0) + rows
        handle._seg_rows[s] = got
        return got
