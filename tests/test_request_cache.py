"""Request-cache (paper §I.B "caching") and byte-tokenizer tests."""
import numpy as np
import jax

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.data import tokenizer as tok
from repro.serving.request_cache import PredictionCache
from repro.serving.system import InferenceSystem

SEQ = 16


def test_cache_hits_and_order():
    class FakeSystem:
        calls = []

        def predict(self, X):
            FakeSystem.calls.append(X.shape[0])
            return X.sum(axis=1, keepdims=True).astype(np.float32)

    cache = PredictionCache(capacity=100)
    sys_ = FakeSystem()
    X1 = np.arange(12, dtype=np.int32).reshape(4, 3)
    Y1 = cache.predict_through(sys_, X1)
    np.testing.assert_array_equal(Y1[:, 0], X1.sum(1))
    assert cache.misses == 4 and cache.hits == 0

    # repeat 2 rows + 1 new: only the new row goes through
    X2 = np.vstack([X1[1], X1[3], np.array([9, 9, 9], np.int32)])
    Y2 = cache.predict_through(sys_, X2)
    np.testing.assert_array_equal(Y2[:, 0], X2.sum(1))
    assert cache.hits == 2
    assert FakeSystem.calls == [4, 1]


def test_cache_lru_eviction():
    class Echo:
        def predict(self, X):
            return X.astype(np.float32)

    cache = PredictionCache(capacity=2)
    cache.predict_through(Echo(), np.array([[1], [2], [3]], np.int32))
    assert cache.misses == 3
    cache.predict_through(Echo(), np.array([[1]], np.int32))   # evicted
    assert cache.misses == 4


def test_cache_with_real_system():
    cfgs = ensemble("ENS4")[:1]
    params = [M.init_params(jax.random.PRNGKey(0), cfgs[0])]
    alloc = AllocationMatrix(host_cpus(1, memory_bytes=4 * 1024 ** 3),
                             [cfgs[0].name], np.array([[8]]))
    X = np.random.default_rng(0).integers(0, 512, (10, SEQ)).astype(np.int32)
    with InferenceSystem(cfgs, params, alloc, segment_size=16,
                         max_seq=SEQ) as system:
        cache = PredictionCache()
        Y1 = cache.predict_through(system, X)
        Y2 = cache.predict_through(system, X)       # fully cached
    np.testing.assert_array_equal(Y1, Y2)
    assert cache.hits == 10


def test_tokenizer_roundtrip():
    s = "Hello, ensembles! héllo"
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == s


def test_encode_batch_shapes():
    X = tok.encode_batch(["abc", "a much longer string than sixteen"],
                         seq_len=16, vocab_size=512)
    assert X.shape == (2, 16) and X.dtype == np.int32
    assert int(X.max()) < 512


def test_text_corpus_learnable():
    import jax
    from repro.configs import get_config
    from repro.training import optimizer as opt
    from repro.training.train_loop import train
    cfg = get_config("musicgen-large").reduced()
    corpus = tok.TextCorpus("the quick brown fox jumps over the lazy dog. " * 50,
                            seq_len=32, vocab_size=cfg.vocab_size)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    _, hist = train(cfg, params, corpus.iterator(8), ocfg, steps=40,
                    log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5   # repeated text memorizes
