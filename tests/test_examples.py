"""Examples stay runnable: compile-check all, execute the fastest end-to-end."""
import os
import py_compile
import subprocess
import sys

import pytest

ROOT = __file__.rsplit("/tests", 1)[0]
EXAMPLES = ["quickstart.py", "serve_ensemble.py", "train_lm.py",
            "allocation_search.py", "generate.py"]


@pytest.mark.parametrize("name", EXAMPLES)
def test_example_compiles(name):
    py_compile.compile(os.path.join(ROOT, "examples", name), doraise=True)


def test_allocation_search_runs():
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "allocation_search.py"),
         "--ensemble", "ENS4", "--gpus", "2", "--max-iter", "2",
         "--max-neighs", "10"],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "Algorithm 2" in out.stdout


def test_generate_runs():
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "generate.py"),
         "--arch", "musicgen-large", "--steps", "15", "--tokens", "8"],
        capture_output=True, text=True, timeout=500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated:" in out.stdout
