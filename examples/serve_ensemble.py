"""End-to-end serving driver (deliverable b): optimize + deploy an ensemble
behind the HTTP server, fire batched client requests at it, report latency /
throughput, then shut down.

Run:  PYTHONPATH=src python examples/serve_ensemble.py [--ensemble ENS4]
      [--port 8650] [--requests 24] [--combine mean|weighted|vote|pallas]
"""
import argparse
import json
import os
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationOptimizer, MeasuredBench, host_cpus
from repro.serving.server import serve
from repro.serving.system import InferenceSystem

SEQ = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ensemble", default="ENS4")
    ap.add_argument("--members", type=int, default=3)
    ap.add_argument("--port", type=int, default=8650)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--combine", default="mean")
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--reconfig", action="store_true",
                    help="attach the online reconfiguration controller "
                         "(live replanning + cross-worker work stealing, "
                         "DESIGN.md §8); its stats appear under "
                         "'controller' in GET /metrics")
    args = ap.parse_args()

    cfgs = ensemble(args.ensemble)[: args.members]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    print("members:", [c.name for c in cfgs])

    devices = host_cpus(args.devices, memory_bytes=4 * 1024 ** 3)
    calib = np.random.default_rng(0).integers(
        0, cfgs[0].vocab_size, (64, SEQ)).astype(np.int32)
    bench = MeasuredBench(cfgs, params, calib, segment_size=32)
    result = AllocationOptimizer(cfgs, devices, bench, max_iter=1,
                                 max_neighs=4, batch_sizes=(8, 16),
                                 seq=SEQ).optimize()
    print("allocation:\n" + result.matrix.pretty())

    system = InferenceSystem(cfgs, params, result.matrix, segment_size=32,
                             max_seq=SEQ, combine=args.combine)
    if args.reconfig:
        from repro.serving.control import ReconfigController
        ReconfigController(system, interval_s=2.0,
                           batch_sizes=(8, 16)).start()
        print("reconfig controller attached (replan + work stealing)")
    httpd, batcher = serve(system, port=args.port, max_wait_s=0.05)
    print(f"serving on http://127.0.0.1:{args.port}")

    lat, lock = [], threading.Lock()

    def client(i):
        """Every 4th request is latency-sensitive: it rides /v2/predict with
        priority=high + a deadline; the rest use the v1 /predict shim."""
        x = np.random.default_rng(i).integers(
            0, cfgs[0].vocab_size, (4, SEQ)).tolist()
        high = i % 4 == 0
        path, payload = ("/v2/predict",
                         {"tokens": x, "priority": "high",
                          "deadline_ms": 120_000}) if high \
            else ("/predict", {"tokens": x})
        t0 = time.perf_counter()
        req = urllib.request.Request(
            f"http://127.0.0.1:{args.port}{path}",
            data=json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"})
        y = json.load(urllib.request.urlopen(req))["predictions"]
        with lock:
            lat.append((high, time.perf_counter() - t0))
        assert len(y) == 4

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(180)
    wall = time.perf_counter() - t0
    n = args.requests * 4
    print(f"\n{args.requests} concurrent requests x4 samples: "
          f"{n / wall:.1f} samples/s")
    for label, flag in (("high(v2)", True), ("normal(v1)", False)):
        ls = [l for h, l in lat if h is flag]
        if ls:
            print(f"latency[{label}] p50={np.percentile(ls, 50)*1000:.0f}ms "
                  f"p95={np.percentile(ls, 95)*1000:.0f}ms")
    metrics = json.load(urllib.request.urlopen(
        f"http://127.0.0.1:{args.port}/metrics"))
    print(f"padding efficiency: "
          f"{metrics['counters'].get('padding_efficiency', 1.0):.3f}")
    if args.reconfig and metrics.get("controller"):
        ctl = metrics["controller"]
        print(f"reconfig: generation={ctl['generation']} "
              f"counters={ctl['counters']}")
    httpd.shutdown()
    batcher.stop()
    system.shutdown()


if __name__ == "__main__":
    main()
