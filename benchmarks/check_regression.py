"""CI gate: compare a fresh ``BENCH_serving.json`` against the committed
baseline (``benchmarks/BENCH_baseline.json``) and fail on regression.

    PYTHONPATH=src python -m benchmarks.check_regression BENCH_serving.json \
        [--baseline benchmarks/BENCH_baseline.json] [--tolerance 0.30]

Only machine-independent *relative* metrics are gated (speedups, ratios,
padding efficiency) — absolute segments/sec varies with the runner's
hardware, but the engine-vs-engine ratios measured on one box should hold on
another.  A metric fails when ``current < baseline * (1 - tolerance)``.
"""
from __future__ import annotations

import argparse
import json
import sys

# dotted paths into the "serving" section of BENCH_serving.json as
# (metric, relative_tolerance, absolute_floor).  relative_tolerance None ->
# the global --tolerance; the effective floor is max(relative, absolute).
# large_request_ratio enforces the documented acceptance bound — coalescing
# within 5% of the PR-1 engine on single large requests — as an absolute
# floor of 0.90 (5% criterion + 5% allowance for shared-runner noise)
# rather than a tolerance on the committed ~1.0 baseline.
# mixed_priority gates the ISSUE-3 acceptance: high-priority p99 >= 3x
# better than strict FIFO (absolute floor; the wide relative tolerance
# absorbs cross-runner tail-latency noise on the committed baseline) with
# total throughput within 10% of FIFO (0.90 absolute floor).
# skewed_load gates the ISSUE-4 acceptance: work stealing >= 1.3x throughput
# under a 4:1 per-member load skew (absolute floor; the scenario runs on
# simulated device time, so it is deterministic across runners).
GATED_METRICS = [
    ("speedup", None, None),                  # pipelined engine vs seed
    ("large_request_ratio", None, 0.90),      # coalesced vs PR-1, big request
    ("many_small.speedup", None, None),       # coalesced vs PR-1, small reqs
    ("many_small.coalesced.padding_efficiency", 0.15, None),
    ("mixed_priority.hp_p99_improvement", 0.70, 3.0),
    ("mixed_priority.throughput_ratio", None, 0.90),
    ("skewed_load.steal_throughput_ratio", None, 1.30),
]


def lookup(d: dict, dotted: str):
    for part in dotted.split("."):
        d = d[part]
    return d


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="fresh BENCH_serving.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    args = ap.parse_args()

    with open(args.results) as f:
        current = json.load(f)["serving"]
    with open(args.baseline) as f:
        baseline = json.load(f)["serving"]

    failures = []
    for metric, tol, abs_floor in GATED_METRICS:
        tol = args.tolerance if tol is None else tol
        base = float(lookup(baseline, metric))
        cur = float(lookup(current, metric))
        floor = base * (1.0 - tol)
        if abs_floor is not None:
            floor = max(floor, abs_floor)
        status = "OK " if cur >= floor else "FAIL"
        print(f"{status} {metric}: current={cur:.3f} baseline={base:.3f} "
              f"floor={floor:.3f}")
        if cur < floor:
            failures.append(metric)

    if failures:
        print(f"regression in: {', '.join(failures)}", file=sys.stderr)
        return 1
    print("no regression beyond tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
