"""Paper §IV.B stability analysis: relative standard deviation of the
measured throughput for a fixed allocation matrix (paper: RSD < 2%), and the
volatility of the bounded greedy's result across seeds when
max_neighs/total_neighs is low (paper: up to RSD = 16%)."""
from __future__ import annotations

import numpy as np

from repro.configs import ensemble
from repro.core import (AllocationOptimizer, AnalyticBench, MeasuredBench,
                        host_cpus, simulated_gpus)

GiB = 1024 ** 3


def bench_rsd(repeats=5, n_samples=128, seq=16, csv=True):
    import jax
    import repro.models as M
    from repro.core import AllocationMatrix
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    devs = host_cpus(1, memory_bytes=4 * GiB)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs],
                             np.array([[8, 16]]))
    X = np.random.default_rng(0).integers(0, 512, (n_samples, seq)).astype(np.int32)
    from repro.serving.system import InferenceSystem
    scores = []
    with InferenceSystem(cfgs, params, alloc, segment_size=32,
                         max_seq=seq) as system:
        for _ in range(repeats):
            _, thr = system.benchmark(X)
            scores.append(thr)
    rsd = 100.0 * np.std(scores) / np.mean(scores)
    if csv:
        print(f"stability:bench_rsd_pct,{rsd:.2f}")
    return rsd


def greedy_volatility(seeds=(0, 1, 2, 3, 4), max_neighs=15, csv=True):
    cfgs = ensemble("ENS4")
    devices = simulated_gpus(4, memory_bytes=int(0.15 * GiB)) + \
        host_cpus(1, 1 * GiB)
    finals = []
    for s in seeds:
        bench = AnalyticBench(cfgs, seq=128)
        opt = AllocationOptimizer(cfgs, devices, bench, max_iter=10,
                                  max_neighs=max_neighs, seed=s)
        finals.append(opt.optimize().final_score)
    rsd = 100.0 * np.std(finals) / np.mean(finals)
    if csv:
        print(f"stability:greedy_rsd_pct_maxneighs{max_neighs},{rsd:.2f}")
    return rsd


def run(csv=True):
    return {"bench_rsd": bench_rsd(csv=csv),
            "greedy_rsd": greedy_volatility(csv=csv)}


if __name__ == "__main__":
    run()
