"""Correctness of the shard_map flash-decoding path (§Perf variant
"cache_seqshard") vs the single-device decode, on an 8-device host mesh
(subprocess: needs XLA_FLAGS before jax init)."""
import subprocess
import sys
import textwrap

ROOT = __file__.rsplit("/tests", 1)[0]


def test_flash_decode_matches_plain():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.collectives import flash_decode
        from repro.kernels.ref import decode_attention_ref

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, L, H, KV, hd = 4, 32, 4, 2, 16
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 5)
        q = jax.random.normal(ks[0], (B, 1, H, hd))
        kc = jax.random.normal(ks[1], (B, L, KV, hd))
        vc = jax.random.normal(ks[2], (B, L, KV, hd))
        kn = jax.random.normal(ks[3], (B, 1, KV, hd))
        vn = jax.random.normal(ks[4], (B, 1, KV, hd))

        for window, pos in [(0, 20), (0, 31), (16, 20), (16, 37 % 32 + 16)]:
            # reference: update cache in numpy then dense masked attention
            L_ = L
            slot = pos % L_ if window > 0 else pos
            kc_ref = np.asarray(kc).copy(); vc_ref = np.asarray(vc).copy()
            kc_ref[:, slot] = np.asarray(kn[:, 0]); vc_ref[:, slot] = np.asarray(vn[:, 0])
            idx = np.arange(L_)
            if window > 0:
                k_pos = pos - ((pos - idx) % L_)
            else:
                k_pos = idx
            valid = (k_pos <= pos) & (k_pos >= 0)
            if window > 0:
                valid &= k_pos > pos - window
            # scale is applied inside both paths via 1/sqrt(hd)
            exp = decode_attention_ref(q, jnp.asarray(kc_ref), jnp.asarray(vc_ref),
                                       jnp.asarray(valid))
            with mesh:
                out, kc2, vc2 = flash_decode(mesh, q, kc, vc, kn, vn,
                                             jnp.int32(pos), window=window)
            err = float(jnp.abs(out - exp).max())
            assert err < 1e-5, (window, pos, err)
            np.testing.assert_allclose(np.asarray(kc2), kc_ref, atol=1e-6)
        print("OK flash_decode")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=300)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    assert "OK flash_decode" in out.stdout
