"""Autoregressive generation through the prefill + decode_step substrate —
the serving-side decode path that the decode_32k / long_500k dry-run shapes
lower at pod scale, here running end-to-end on CPU with a reduced model.

Trains a tiny model on repeated text first (so generation shows learned
structure), then decodes greedily from a prompt, optionally with the int8
KV cache.

Run:  PYTHONPATH=src python examples/generate.py [--arch gemma3-1b]
          [--steps 150] [--int8-cache] [--tokens 80]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config
from repro.data import tokenizer as tok
from repro.training import optimizer as opt
from repro.training.train_loop import train

TEXT = ("the quick brown fox jumps over the lazy dog. "
        "pack my box with five dozen liquor jugs. ") * 40


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--tokens", type=int, default=80)
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--prompt", default="the quick brown ")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"model: {cfg.name} ({cfg.param_count():,} params), "
          f"int8 cache: {args.int8_cache}")

    corpus = tok.TextCorpus(TEXT, seq_len=64, vocab_size=cfg.vocab_size)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    params, hist = train(cfg, params, corpus.iterator(16), ocfg,
                         steps=args.steps, log_every=50,
                         callback=lambda m: print(
                             f"  step {m['step']:4d} loss {m['loss']:.3f}"))

    prompt_ids = np.asarray(tok.encode(args.prompt, bos=False),
                            np.int32) % cfg.vocab_size
    max_len = len(prompt_ids) + args.tokens
    tokens = jnp.asarray(prompt_ids)[None, :]
    logits, cache = M.prefill(params, cfg, tokens, max_len,
                              quantize_cache=args.int8_cache)

    decode = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    out = list(prompt_ids)
    tok_next = int(jnp.argmax(logits[0]))
    for i in range(args.tokens):
        out.append(tok_next)
        logits, cache = decode(params, cache,
                               jnp.asarray([[tok_next]], jnp.int32),
                               jnp.int32(len(out) - 1))
        tok_next = int(jnp.argmax(logits[0]))

    print("\nprompt:    " + repr(args.prompt))
    print("generated: " + repr(tok.decode(out[len(prompt_ids):])))


if __name__ == "__main__":
    main()
