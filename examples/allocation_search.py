"""Allocation-matrix optimizer walkthrough (paper §II.E, Tables I-III).

Shows Algorithm 1 (worst-fit-decreasing) and Algorithm 2 (bounded greedy) on
the paper-shaped scenario — an ensemble on N simulated V100s + 1 CPU — with
the analytic roofline bench, printing the Table-II-style matrix at each
stage and the BBS baseline comparison.

Run:  PYTHONPATH=src python examples/allocation_search.py [--ensemble ENS12]
          [--gpus 4]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ensemble
from repro.core import (AllocationMatrix, AllocationOptimizer, AnalyticBench,
                        MemoBench, host_cpus, simulated_gpus,
                        worst_fit_decreasing)
from repro.core.bbs import BBSError, analytic_single_bench, best_batch_strategy

GiB = 1024 ** 3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ensemble", default="ENS4")
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--gpu-mem-mib", type=int, default=150)
    ap.add_argument("--max-iter", type=int, default=10)
    ap.add_argument("--max-neighs", type=int, default=100)
    args = ap.parse_args()

    cfgs = ensemble(args.ensemble)
    devices = simulated_gpus(args.gpus,
                             memory_bytes=args.gpu_mem_mib * 1024 ** 2) + \
        host_cpus(1, memory_bytes=1 * GiB)
    print(f"{len(cfgs)} models on {args.gpus} GPUs + 1 CPU")
    print("decision space (Eq. 1): "
          f"{AllocationMatrix.total_matrices(len(devices), len(cfgs), 5):.2e} matrices\n")

    bench = MemoBench(AnalyticBench(cfgs, seq=128))

    wfd = worst_fit_decreasing(cfgs, devices)
    print(f"Algorithm 1 (worst-fit-decreasing): {bench(wfd):.0f} samples/s")
    print(wfd.pretty(), "\n")

    opt = AllocationOptimizer(cfgs, devices, bench, max_iter=args.max_iter,
                              max_neighs=args.max_neighs)
    res = opt.optimize()
    print(f"Algorithm 2 (bounded greedy, {res.trace.evaluated} benches, "
          f"{res.trace.iterations} iterations): {res.final_score:.0f} samples/s "
          f"({res.final_score / max(res.wfd_score, 1e-9):.2f}x)")
    print(res.matrix.pretty(), "\n")
    print("greedy score trajectory:",
          [round(s) for s in res.trace.scores])

    try:
        bbs, nb = best_batch_strategy(cfgs, devices,
                                      analytic_single_bench(seq=128))
        print(f"\nBBS baseline ({nb} benches): {bench(bbs):.0f} samples/s "
              f"-> our speedup {res.final_score / max(bench(bbs), 1e-9):.2f}x")
    except BBSError as e:
        print(f"\nBBS baseline inapplicable: {e}")


if __name__ == "__main__":
    main()
