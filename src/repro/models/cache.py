"""Decode-state pytrees: KV ring buffers, SSM states, cross-attn KV.

Cache layout mirrors the parameter layout: ``cache["layers"]`` is a list with
one entry per pattern-unit position; every leaf carries a leading ``repeats``
dimension so the layer stack can ``lax.scan`` over it.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.configs.base import ATTN, CROSS, HYBRID, SSM, SWA, ModelConfig

# Per-(…, head) symmetric int8 over head_dim: (..., hd) -> (q int8, scale f32
# (..., 1)).  Halves decode KV-streaming bytes (the dominant roofline term for
# decode shapes) at ~1e-2 relative attention error.  The math lives in the
# shared quantization module; re-exported here for the historical import path.
from repro.kernels.quant import dequantize_kv, quantize_kv  # noqa: F401


def layer_cache_struct(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                       dtype=jnp.float32, *, quantized: bool = False
                       ) -> Dict[str, Any]:
    """Shapes (without the leading repeats dim) of one layer's cache."""
    out: Dict[str, Any] = {}
    kv, hd = cfg.num_kv_heads, cfg.hd

    def kv_entry(L):
        if quantized:
            out["k"] = ((batch, L, kv, hd), jnp.int8)
            out["v"] = ((batch, L, kv, hd), jnp.int8)
            out["k_scale"] = ((batch, L, kv, 1), jnp.float32)
            out["v_scale"] = ((batch, L, kv, 1), jnp.float32)
        else:
            out["k"] = ((batch, L, kv, hd), dtype)
            out["v"] = ((batch, L, kv, hd), dtype)

    if kind in (ATTN, SWA, HYBRID):
        kv_entry(max_len if kind == ATTN else min(max_len, cfg.sliding_window))
    if kind == CROSS:
        kv_entry(cfg.frontend_tokens)
    if kind in (SSM, HYBRID):
        s = cfg.ssm
        out["h"] = ((batch, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32)
        out["conv"] = ((batch, s.d_conv - 1, cfg.d_inner + 2 * s.d_state), dtype)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
               *, quantized: bool = False):
    """Zero-initialized cache pytree."""
    layers = []
    for kind in cfg.pattern:
        entry = {}
        for name, (shape, dt) in layer_cache_struct(
                cfg, kind, batch, max_len, dtype, quantized=quantized).items():
            entry[name] = jnp.zeros((cfg.repeats,) + shape, dt)
        layers.append(entry)
    return {"layers": layers}


def cache_struct(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
                 *, quantized: bool = False):
    """ShapeDtypeStruct version (for dry-run lowering, no allocation)."""
    import jax
    layers = []
    for kind in cfg.pattern:
        entry = {}
        for name, (shape, dt) in layer_cache_struct(
                cfg, kind, batch, max_len, dtype, quantized=quantized).items():
            entry[name] = jax.ShapeDtypeStruct((cfg.repeats,) + shape, dt)
        layers.append(entry)
    return {"layers": layers}
