"""Pod-scale serving launcher: optimize an ensemble allocation over TPU cells
and start the inference server.

On real hardware, cells are sub-mesh slices (core.devices.tpu_cells); on this
container the same code path runs with CPU-backed logical devices.

    python -m repro.launch.serve --ensemble ENS4 --cells 2 --port 8600
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ensemble", default="ENS4")
    ap.add_argument("--members", type=int, default=0)
    ap.add_argument("--cells", type=int, default=2)
    ap.add_argument("--cell-mem-gib", type=float, default=4.0)
    ap.add_argument("--port", type=int, default=8600)
    ap.add_argument("--segment-size", type=int, default=32)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--combine", default="mean")
    ap.add_argument("--member-dtype", default="fp32",
                    help="member execution precision (DESIGN.md §14): one "
                         "value for all members (fp32|bf16|int8|fp8) or a "
                         "comma-separated per-member list, e.g. "
                         "'int8,int8,fp32,fp32'.  Quantized members store "
                         "params narrow (per-output-channel scales), pack "
                         "~2x denser in the allocator, and feed the fused "
                         "dequant-combine epilogue")
    ap.add_argument("--dispatch-queue", default="fifo",
                    choices=("fifo", "edf"),
                    help="predictor dispatch order: fifo = strict priority "
                         "then arrival; edf = earliest-deadline-first "
                         "within priority class (simulator-validated, "
                         "DESIGN.md §12)")
    ap.add_argument("--bench", default="measured", choices=("measured", "analytic"))
    ap.add_argument("--duration", type=float, default=0.0,
                    help="serve for N seconds then exit (0 = forever)")
    ap.add_argument("--linger", default="fixed", choices=("fixed", "adaptive"),
                    help="adaptive scales the coalescing linger down with "
                         "queue depth (DESIGN.md §7)")
    ap.add_argument("--max-wait-us", type=int, default=500,
                    help="coalescing linger bound per open batch slot")
    ap.add_argument("--dispatch-ahead", type=int, default=0,
                    help="committed (non-preemptible) chunk window per "
                         "worker: small (1-2) favors high-priority latency, "
                         "large favors throughput; 0 = library default "
                         "(DESIGN.md §3)")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="rows in the prediction cache (0 disables)")
    ap.add_argument("--reconfig", action="store_true",
                    help="run the online reconfiguration controller: live "
                         "replanning against the EWMA workload profile plus "
                         "cross-worker work stealing (DESIGN.md §8)")
    ap.add_argument("--reconfig-interval", type=float, default=5.0,
                    help="seconds between live replans (with --reconfig)")
    ap.add_argument("--steal-threshold", type=int, default=4,
                    help="queue-depth gap between data-parallel siblings "
                         "that triggers work stealing (with --reconfig)")
    ap.add_argument("--no-steal", action="store_true",
                    help="disable the work-stealing fast path (replanning "
                         "only, with --reconfig)")
    # fault tolerance (DESIGN.md §10)
    ap.add_argument("--no-supervise", action="store_true",
                    help="disable worker supervision and fall back to the "
                         "paper's all-or-nothing failure model: any worker "
                         "crash fails every in-flight request and shuts the "
                         "system down (§II.C.2)")
    ap.add_argument("--watchdog-s", type=float, default=5.0,
                    help="a worker stage mid-work longer than this is "
                         "declared stalled and its instance quarantined")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="max times one request's chunks may be resubmitted "
                         "after worker failures before it fails with "
                         "RetriesExhausted (HTTP 503)")
    ap.add_argument("--nan-guard", action="store_true",
                    help="check materialized device outputs for NaN; a "
                         "poisoned output crashes its worker (quarantine + "
                         "replay on a sibling) instead of folding into Y")
    ap.add_argument("--fault", action="append", default=[],
                    metavar="SPEC",
                    help="inject a deterministic fault for chaos testing; "
                         "repeatable.  SPEC is key=value pairs: "
                         "stage=batcher|predictor|sender|spawn "
                         "[kind=raise|stall|nan|slow] [after=N] [stall_s=S] "
                         "[repeat=true] [worker=ID-prefix], e.g. "
                         "--fault stage=predictor,after=100,worker=w0.0 or "
                         "a sustained overload drill: "
                         "--fault stage=predictor,kind=slow,stall_s=0.004")
    # overload robustness (DESIGN.md §11)
    ap.add_argument("--brownout", action="store_true",
                    help="run the brownout controller: continuous pressure "
                         "signal from queue depths / p99 / loss counters, "
                         "hysteresis into discrete levels, each serving a "
                         "cheaper member-subset quality tier; plus cost-"
                         "aware admission (429 + computed Retry-After on "
                         "infeasible deadlines)")
    ap.add_argument("--tier-table", default=None,
                    help="explicit brownout tiers as semicolon-separated "
                         "member-id lists, level 0 first, e.g. "
                         "'0,1,2;0,1;0'; default derives tiers from "
                         "per-member cost/weight ratios (EARN-style)")
    ap.add_argument("--brownout-deadline-ms", type=float, default=None,
                    help="latency budget the pressure signal compares the "
                         "normal-class p99 against (default: none — queue "
                         "depth and loss counters drive pressure)")
    ap.add_argument("--cascade-margin", type=float, default=None,
                    help="confidence-gated cascade: tier results whose "
                         "top1-top2 margin falls below this escalate to the "
                         "dropped members (with --brownout)")
    # simulation / planning (DESIGN.md §12)
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="append every offered request to PATH as JSONL "
                         "(t, rows, priority, deadline_ms, members) for "
                         "offline replay: benchmarks/serving_hotpath.py "
                         "--replay-trace or the discrete-event simulator "
                         "(repro.serving.sim)")
    ap.add_argument("--admission-budget-mib", type=float, default=0.0,
                    help="global in-flight input-byte budget; requests "
                         "beyond it are refused with 429 + Retry-After "
                         "instead of queuing unboundedly (0 disables)")
    # observability / tracing (DESIGN.md §13)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write the flight "
                         "recorder as Chrome-trace / Perfetto JSON to PATH "
                         "at shutdown (also live at GET /v2/trace); open "
                         "it at https://ui.perfetto.dev")
    ap.add_argument("--flight-recorder", type=int, default=0, metavar="N",
                    help="per-track flight-recorder ring capacity in "
                         "events (enables tracing without --trace-out; "
                         "anomalies — watchdog stalls, deadline-miss "
                         "bursts, brownout shifts, exhausted retries — "
                         "freeze tagged dumps at GET /v2/trace?dumps=1; "
                         "0 = off unless --trace-out, default ring 4096)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    import repro.models as M
    from repro.configs import ensemble
    from repro.core import (AllocationOptimizer, AnalyticBench, MeasuredBench,
                            host_cpus, tpu_cells)
    from repro.serving.request_cache import PredictionCache
    from repro.serving.server import serve
    from repro.serving.system import InferenceSystem

    cfgs = ensemble(args.ensemble)
    if args.members:
        cfgs = cfgs[: args.members]
    from repro.kernels.quant import validate_member_dtype
    dts = [d.strip() for d in args.member_dtype.split(",") if d.strip()]
    if len(dts) == 1:
        dts = dts * len(cfgs)
    if len(dts) != len(cfgs):
        ap.error(f"--member-dtype expects 1 or {len(cfgs)} values, "
                 f"got {len(dts)}")
    member_dtypes = [validate_member_dtype(d) for d in dts]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]

    tpus = [d for d in jax.devices() if d.platform == "tpu"]
    if tpus:
        devices = tpu_cells(tpus, cell_size=max(1, len(tpus) // args.cells))
    else:
        devices = host_cpus(args.cells,
                            memory_bytes=int(args.cell_mem_gib * 1024 ** 3))

    calib = np.random.default_rng(0).integers(
        0, cfgs[0].vocab_size, (64, args.seq)).astype(np.int32)
    if args.bench == "measured":
        bench = MeasuredBench(cfgs, params, calib,
                              segment_size=args.segment_size)
        opt = AllocationOptimizer(cfgs, devices, bench, max_iter=1,
                                  max_neighs=4, batch_sizes=(8, 16),
                                  seq=args.seq,
                                  cache_path=".repro_alloc_cache.json",
                                  member_dtypes=member_dtypes)
    else:
        bench = AnalyticBench(cfgs, seq=args.seq,
                              member_dtypes=member_dtypes)
        opt = AllocationOptimizer(cfgs, devices, bench, max_iter=10,
                                  max_neighs=100, seq=args.seq,
                                  cache_path=".repro_alloc_cache.json",
                                  member_dtypes=member_dtypes)
    res = opt.optimize()
    print("allocation matrix:\n" + res.matrix.pretty())
    print(f"bench: A1={res.wfd_score:.1f} -> A2={res.final_score:.1f} "
          f"samples/s{' (cached)' if res.from_cache else ''}")

    fault_plan = None
    if args.fault:
        from repro.serving.faults import FaultPlan, FaultSpec
        fault_plan = FaultPlan(*[FaultSpec.parse(s) for s in args.fault])
        print(f"fault injection armed: {args.fault}")
    budget = None
    if args.admission_budget_mib:
        from repro.serving.admission import AdmissionBudget
        budget = AdmissionBudget(
            max_bytes=int(args.admission_budget_mib * 1024 ** 2))
    trace_cap = args.flight_recorder or (4096 if args.trace_out else 0)
    system = InferenceSystem(cfgs, params, res.matrix,
                             segment_size=args.segment_size,
                             max_seq=args.seq, combine=args.combine,
                             max_wait_us=args.max_wait_us,
                             linger=args.linger,
                             dispatch_ahead=args.dispatch_ahead or None,
                             supervise=not args.no_supervise,
                             watchdog_s=args.watchdog_s,
                             retry_budget=args.retry_budget,
                             nan_guard=args.nan_guard,
                             fault_plan=fault_plan,
                             admission_budget=budget,
                             tracing=trace_cap > 0,
                             trace_capacity=trace_cap or 4096,
                             member_dtypes=member_dtypes,
                             dispatch_queue=args.dispatch_queue)
    if any(d != "fp32" for d in member_dtypes):
        print(f"member dtypes: {','.join(member_dtypes)} (quantized members "
              f"run the fused dequant-combine epilogue)")
    if args.dispatch_queue != "fifo":
        print(f"dispatch queue: {args.dispatch_queue}")
    if trace_cap:
        print(f"span tracing on (flight recorder {trace_cap} events/track; "
              f"GET /v2/trace, anomaly dumps at ?dumps=1)")
    if not args.no_supervise:
        print(f"supervision on (watchdog {args.watchdog_s:.1f}s, retry "
              f"budget {args.retry_budget}); worker failures quarantine the "
              f"instance — health gauges in GET /metrics")
    controller = None
    if args.reconfig:
        from repro.serving.control import ReconfigController
        controller = ReconfigController(
            system, interval_s=args.reconfig_interval,
            steal_threshold=args.steal_threshold,
            steal=not args.no_steal, batch_sizes=(8, 16, 32)).start()
        print(f"reconfig controller on (replan every "
              f"{args.reconfig_interval:.1f}s, steal "
              f"{'off' if args.no_steal else 'on'}; see GET /metrics "
              f"'controller')")
    brownout = None
    if args.brownout:
        from repro.serving.control import BrownoutController
        tiers = None
        if args.tier_table:
            tiers = [tuple(int(m) for m in level.split(","))
                     for level in args.tier_table.split(";") if level.strip()]
        brownout = BrownoutController(
            system, tiers=tiers,
            deadline_budget_ms=args.brownout_deadline_ms,
            cascade_margin=args.cascade_margin).start()
        print(f"brownout controller on ({len(brownout.tiers())} quality "
              f"tiers; see GET /metrics 'brownout')")
    if budget is not None:
        print(f"admission budget: {args.admission_budget_mib:.1f} MiB "
              f"in-flight input bytes (429 + Retry-After beyond it)")
    recorder = None
    if args.record_trace:
        from repro.serving.trace import TraceRecorder
        recorder = TraceRecorder(path=args.record_trace)
        system.trace_recorder = recorder
        print(f"recording request trace to {args.record_trace}")
    cache = PredictionCache(args.cache_capacity) if args.cache_capacity else None
    httpd, batcher = serve(system, port=args.port, cache=cache)
    print(f"serving {len(cfgs)} models / {len(system.workers)} workers on "
          f"http://127.0.0.1:{args.port}  (POST /v2/predict with priority/"
          f"deadline_ms/members, GET /metrics; POST /predict = v1 shim)")
    try:
        if args.duration:
            time.sleep(args.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()
        batcher.stop()
        system.shutdown()
        if recorder is not None:
            recorder.close()
            print(f"trace: {len(recorder.events())} requests recorded to "
                  f"{args.record_trace}")
        if args.trace_out:
            import json
            trace = system.tracer.export()
            with open(args.trace_out, "w") as f:
                json.dump(trace, f)
            print(f"span timeline: {len(trace['traceEvents'])} events "
                  f"written to {args.trace_out} (open at "
                  f"https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
