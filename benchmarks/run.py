"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table3,...]
                                            [--json BENCH_serving.json]

Emits CSV lines ``<table>:<fields...>`` so results can be grepped/diffed, and
writes a machine-readable ``BENCH_serving.json`` with the serving results
(segments/sec, per-stage timings, overhead) for CI trend tracking.
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: overhead,serving,sim,table1,table3,"
                         "stability,roofline")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="path for the machine-readable serving results "
                         "('' disables)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed for the serving job (recorded "
                         "in the JSON as serving.rng_seed)")
    ap.add_argument("--scenario", action="append", default=None,
                    help="serving scenario to run (repeatable); default all "
                         "— see benchmarks/serving_hotpath.py SCENARIOS")
    args = ap.parse_args()
    want = set(filter(None, args.only.split(",")))

    from benchmarks import (overhead, roofline_report, serving_hotpath,
                            sim_bench, stability, table1_throughput,
                            table3_bbs)
    jobs = [
        ("overhead", overhead.run),          # paper §IV.A
        ("serving",                          # hot-path A/B (ISSUE 1)
         lambda: serving_hotpath.run(seed=args.seed,
                                     scenarios=args.scenario)),
        ("sim", sim_bench.run),              # discrete-event sim (ISSUE 8)
        ("table1", table1_throughput.run),   # paper Table I
        ("table3", table3_bbs.run),          # paper Table III
        ("stability", stability.run),        # paper §IV.B
        ("roofline", roofline_report.run),   # deliverable (g)
    ]
    serving_results = {}
    for name, fn in jobs:
        if want and name not in want:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            result = fn()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}:ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        if name in ("overhead", "serving", "sim") and isinstance(result, dict):
            serving_results[name] = result
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)

    if args.json and serving_results:
        with open(args.json, "w") as f:
            json.dump(serving_results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
