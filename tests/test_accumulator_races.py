"""Race coverage for PredictionAccumulator completion: ``fail()`` /
``cancel()`` racing normal completion must resolve each request exactly
once — one ``on_complete`` call (the in-flight window is a
BoundedSemaphore: a double release raises), one error-or-result, stale
messages and stale handles ignored."""
import threading

import numpy as np
import pytest

from repro.serving.accumulator import PredictionAccumulator
from repro.serving.segments import (Message, Request, RequestCancelled,
                                    WorkerCrashed)

C = 4


def make_req(rid, n=8, seg=4, members=(0, 1)):
    return Request(rid=rid, x=np.zeros((n, 4), np.int32), n=n,
                   num_classes=C, segment_size=seg, members=list(members),
                   weights={m: 1.0 / len(members) for m in members})


def data_messages(req):
    """Every per-member message the pipeline would produce for ``req``."""
    out = []
    for s in range(req.num_segments()):
        lo, hi = req.bounds(s)
        for m in req.members:
            out.append(Message(s, m, np.ones((hi - lo, C), np.float32),
                               rid=req.rid))
    return out


class Harness:
    """Accumulator + the system's semantics around it: one bounded
    in-flight slot released by on_complete (double release raises)."""

    def __init__(self):
        self.q = __import__("queue").Queue()
        self.completions = []
        self.release_errors = []
        self.sem = threading.BoundedSemaphore(1)
        self.acc = PredictionAccumulator(self.q, 2,
                                         on_complete=self._on_complete)
        self.acc.start()

    def _on_complete(self, handle):
        self.completions.append(handle.req.rid)
        try:
            self.sem.release()
        except ValueError as e:           # double release: the bug we hunt
            self.release_errors.append(e)

    def begin(self, req):
        self.sem.acquire()
        return self.acc.begin(req)

    def stop(self):
        self.acc.stop()


@pytest.mark.parametrize("resolver", ["fail", "cancel"])
def test_resolution_races_completion_exactly_once(resolver):
    """fail()/cancel() from one thread racing the full message stream from
    another: whatever wins, the handle resolves exactly once and the
    in-flight slot releases exactly once."""
    h = Harness()
    try:
        for rid in range(120):
            req = make_req(rid)
            handle = h.begin(req)
            barrier = threading.Barrier(2)

            def feed():
                barrier.wait()
                for msg in data_messages(req):
                    h.q.put(msg)

            def resolve():
                barrier.wait()
                if resolver == "fail":
                    h.acc.fail(req.rid, WorkerCrashed("boom"))
                else:
                    handle.cancel()

            ts = [threading.Thread(target=feed),
                  threading.Thread(target=resolve)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert handle.done.wait(10.0)
            # exactly one resolution: either the error won or the fold won
            if handle.error is not None:
                exc = WorkerCrashed if resolver == "fail" else RequestCancelled
                assert isinstance(handle.error, exc)
            else:
                assert handle.remaining == 0
                np.testing.assert_allclose(handle.Y, np.ones((8, C)))
            assert handle._finished
        # drain: every rid completed exactly once, no double release
        assert sorted(h.completions) == list(range(120))
        assert h.release_errors == []
        assert h.acc._requests == {}
    finally:
        h.stop()


def test_cancel_then_stragglers_are_stale():
    """Messages that arrive after cancel() resolve nothing, fold nothing,
    and never re-fire on_complete."""
    h = Harness()
    try:
        req = make_req(0)
        handle = h.begin(req)
        assert handle.cancel() is True
        assert handle.cancel() is False       # already resolved
        assert req.dropped()                  # batchers will skip its rows
        with pytest.raises(RequestCancelled):
            handle.result(5.0)
        for msg in data_messages(req):        # stragglers from the pipeline
            h.q.put(msg)
        probe = make_req(99)                  # flush the loop behind a probe
        ph = h.begin(probe)
        for msg in data_messages(probe):
            h.q.put(msg)
        ph.result(10.0)
        assert h.completions == [0, 99]       # each exactly once
        assert not np.any(handle.Y)           # nothing folded after cancel
        assert h.release_errors == []
    finally:
        h.stop()


def test_fail_unknown_rid_is_noop():
    h = Harness()
    try:
        assert h.acc.fail(12345, WorkerCrashed("ghost")) is False
        req = make_req(1)
        handle = h.begin(req)
        for msg in data_messages(req):
            h.q.put(msg)
        handle.result(10.0)
        # late fail on a completed request: stale handle, no effect
        assert h.acc.fail(req.rid, WorkerCrashed("late")) is False
        assert handle.error is None
        assert h.completions == [1] and h.release_errors == []
    finally:
        h.stop()


def test_fail_before_any_rows_then_full_stream():
    """fail() before the first message: the whole stream is stale."""
    h = Harness()
    try:
        req = make_req(2)
        handle = h.begin(req)
        assert h.acc.fail(req.rid, WorkerCrashed("early")) is True
        for msg in data_messages(req):
            h.q.put(msg)
        with pytest.raises(WorkerCrashed):
            handle.result(5.0)
        probe = make_req(3)                   # flush the loop behind a probe
        ph = h.begin(probe)
        for msg in data_messages(probe):
            h.q.put(msg)
        ph.result(10.0)
        assert handle.messages == 0           # nothing folded
        assert h.completions == [2, 3] and h.release_errors == []
    finally:
        h.stop()


def test_concurrent_fail_and_cancel_single_winner():
    """cancel() and fail() racing each other (no data at all): one wins,
    one resolution, one release."""
    h = Harness()
    try:
        for rid in range(100):
            req = make_req(rid)
            handle = h.begin(req)
            barrier = threading.Barrier(2)

            def do_cancel():
                barrier.wait()
                handle.cancel()

            def do_fail():
                barrier.wait()
                h.acc.fail(req.rid, WorkerCrashed("boom"))

            ts = [threading.Thread(target=do_cancel),
                  threading.Thread(target=do_fail)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert handle.done.wait(5.0)
            assert isinstance(handle.error, (RequestCancelled, WorkerCrashed))
        assert len(h.completions) == 100
        assert h.release_errors == []
    finally:
        h.stop()
