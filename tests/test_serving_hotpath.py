"""Hot-path tests (ISSUE 1): combine rules under member subsets, shape-bucket
batching round-trips, device-partial message reduction, multi-request
pipelining, and the versioned input-buffer swap that replaced the shared_x
reallocation race."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.system import InferenceSystem
from repro.serving.worker import bucket_for

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def oracle(cfgs, params, X, weights=None):
    w = weights if weights is not None else [1 / len(cfgs)] * len(cfgs)
    out = np.zeros((X.shape[0], cfgs[0].vocab_size), np.float32)
    for i, (c, p) in enumerate(zip(cfgs, params)):
        fe = jnp.zeros((X.shape[0], c.frontend_tokens, c.fdim)) \
            if c.frontend_tokens else None
        lg, _ = M.forward(p, c, jnp.asarray(X), fe)
        out += np.asarray(lg[:, -1, :c.vocab_size]) * w[i]
    return out


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


# ---- shape buckets ----------------------------------------------------------

def test_bucket_for_shapes():
    assert bucket_for(8, 8) == 8
    assert bucket_for(3, 8) == 8          # min bucket
    assert bucket_for(9, 16) == 16
    assert bucket_for(17, 64) == 32       # next power of two
    assert bucket_for(33, 64) == 64
    assert bucket_for(5, 64) == 8
    assert bucket_for(64, 64) == 64
    assert bucket_for(100, 64) == 64      # clamped to the compiled batch


@pytest.mark.parametrize("n", [1, 7, 8, 9, 20, 31, 32, 70])
def test_batcher_padding_roundtrip(ens2, n):
    """Every request size survives the ring fill / bucket pad / unpad path:
    predictions equal the oracle regardless of how segments chunk."""
    cfgs, params = ens2
    X = np.random.default_rng(n).integers(0, 512, (n, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 16]]), segment_size=32) as s:
        Y = s.predict(X)
    assert Y.shape == (n, cfgs[0].vocab_size)
    np.testing.assert_allclose(Y, oracle(cfgs, params, X), atol=2e-5)


# ---- combine rules under member subsets ------------------------------------

def test_weighted_combine_member_subset(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(2).integers(0, 512, (20, SEQ)).astype(np.int32)
    w = np.array([0.8, 0.2], np.float32)
    with make_system(cfgs, params, np.array([[8, 8]]), combine="weighted",
                     weights=w, segment_size=16) as s:
        y0 = s.predict(X, members=[0])        # weights renormalize to 1.0
        y1 = s.predict(X, members=[1])
    np.testing.assert_allclose(y0, oracle(cfgs[:1], params[:1], X), atol=2e-5)
    np.testing.assert_allclose(y1, oracle(cfgs[1:], params[1:], X), atol=2e-5)


@pytest.mark.parametrize("device_combine", [True, False])
def test_vote_combine_member_subset(ens2, device_combine):
    cfgs, params = ens2
    X = np.random.default_rng(3).integers(0, 512, (20, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), combine="vote",
                     segment_size=16, device_combine=device_combine) as s:
        y_all = s.predict(X)
        y_sub = s.predict(X, members=[0])
    np.testing.assert_allclose(y_all.sum(axis=1), 1.0, atol=1e-6)
    # single-member vote: exactly one class gets weight 1.0 per row
    np.testing.assert_allclose(y_sub.max(axis=1), 1.0, atol=1e-6)
    np.testing.assert_allclose(y_sub.sum(axis=1), 1.0, atol=1e-6)


@pytest.mark.parametrize("device_combine", [True, False])
@pytest.mark.parametrize("n", [37, 40])       # 37: non-block-aligned segments
def test_pallas_combine_non_aligned(ens2, device_combine, n):
    cfgs, params = ens2
    X = np.random.default_rng(4).integers(0, 512, (n, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        Y_mean = s.predict(X)
    with make_system(cfgs, params, np.array([[8, 8]]), combine="pallas",
                     segment_size=16, device_combine=device_combine) as s:
        Y_pallas = s.predict(X)
    np.testing.assert_allclose(Y_mean, Y_pallas, atol=1e-5)

    with make_system(cfgs, params, np.array([[8, 8]]), combine="pallas",
                     segment_size=16, device_combine=device_combine) as s:
        Y_sub = s.predict(X, members=[1])
    np.testing.assert_allclose(Y_sub, oracle(cfgs[1:], params[1:], X),
                               atol=2e-5)


# ---- device-resident partial combine ---------------------------------------

def test_partial_combine_message_reduction(ens2):
    """Co-located workers post one partial per device per segment: messages
    drop from M x segments to devices x segments."""
    cfgs, params = ens2
    X = np.random.default_rng(5).integers(0, 512, (64, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     device_combine=True) as s:
        before = s.accumulator.data_messages
        Y1 = s.predict(X)
        assert s.accumulator.data_messages - before == 4      # 1 dev x 4 segs
        assert s.combiners and all(c.partials_posted for c in
                                   s.combiners.values())
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     device_combine=False) as s:
        before = s.accumulator.data_messages
        Y2 = s.predict(X)
        # senders reassemble spans before forwarding: still M=2 x 4 segs
        assert s.accumulator.data_messages - before == 8
    np.testing.assert_allclose(Y1, Y2, atol=2e-5)


def test_partial_combine_data_parallel(ens2):
    """Striping across data-parallel instances keeps per-device contribution
    counts deterministic; results still match the oracle."""
    cfgs, params = ens2
    X = np.random.default_rng(6).integers(0, 512, (100, SEQ)).astype(np.int32)
    A = np.array([[8, 8],
                  [16, 0]])
    with make_system(cfgs, params, A, segment_size=16,
                     device_combine=True) as s:
        before = s.accumulator.data_messages
        Y = s.predict(X)
        msgs = s.accumulator.data_messages - before
    # 7 segments: model 0 striped over 2 devices, model 1 on device 0 ->
    # device 0 posts 7 partials, device 1 posts ceil(7/2)=4 (odd segments... 3)
    assert msgs < 14                              # strictly fewer than M*segs
    np.testing.assert_allclose(Y, oracle(cfgs, params, X), atol=2e-5)


# ---- multi-request pipelining ----------------------------------------------

def test_predict_async_overlap(ens2):
    cfgs, params = ens2
    rng = np.random.default_rng(7)
    Xs = [rng.integers(0, 512, (24 + 8 * i, SEQ)).astype(np.int32)
          for i in range(5)]
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     max_in_flight=3) as s:
        handles = [s.predict_async(x) for x in Xs]
        Ys = [h.result(120.0) for h in handles]
    for x, y in zip(Xs, Ys):
        np.testing.assert_allclose(y, oracle(cfgs, params, x), atol=2e-5)


def test_inflight_window_bounded(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     max_in_flight=2, fake=True) as s:
        # issuing many requests never exceeds the window; all complete
        handles = [s.predict_async(np.zeros((8, SEQ), np.int32))
                   for _ in range(10)]
        for h in handles:
            assert np.all(h.result(60.0) == 0)


def test_buffer_swap_race_fixed(ens2):
    """Growing a later request can't invalidate an earlier in-flight one:
    each request owns its buffer (the seed reallocated shared_x in place)."""
    cfgs, params = ens2
    rng = np.random.default_rng(8)
    small = rng.integers(0, 512, (16, SEQ)).astype(np.int32)
    big = rng.integers(0, 512, (160, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     max_in_flight=4) as s:
        for _ in range(3):                 # interleave growing requests
            h_small = s.predict_async(small)
            h_big = s.predict_async(big)
            np.testing.assert_allclose(h_small.result(120.0),
                                       oracle(cfgs, params, small), atol=2e-5)
            np.testing.assert_allclose(h_big.result(120.0),
                                       oracle(cfgs, params, big), atol=2e-5)


def test_bad_members_do_not_leak_window_slots(ens2):
    """A rejected submit must release its in-flight slot, or repeated caller
    errors would wedge the window."""
    cfgs, params = ens2
    X = np.zeros((8, SEQ), np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, max_in_flight=2) as s:
        for _ in range(5):
            with pytest.raises(ValueError, match="out of range"):
                s.predict(X, members=[7])
        handles = [s.predict_async(X) for _ in range(4)]   # window still works
        for h in handles:
            h.result(30.0)


def test_stage_timings_populated(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(9).integers(0, 512, (32, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        s.predict(X)
        stages = s.stage_timings()
    for key in ("batcher_wait", "batch_fill", "predict", "transfer",
                "combine", "accumulate"):
        assert key in stages and stages[key]["count"] > 0, (key, stages)
