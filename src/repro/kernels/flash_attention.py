"""Pallas TPU flash attention: blocked causal GQA attention with optional
sliding window.

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the innermost
grid dim is sequential on TPU, so the online-softmax running state (m, l, acc)
lives in VMEM scratch and is carried across kv blocks.  Blocks are
(BLOCK_Q, head_dim) / (BLOCK_KV, head_dim) — head_dim is padded to a multiple
of 128 by the ops wrapper so the MXU contraction dims stay hardware-aligned.

GQA is handled by the k/v index_map (query head h reads kv head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_Q = 128
BLOCK_KV = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_kv: int, seq: int, causal: bool, window: int,
            scale: float, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < seq                       # kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = BLOCK_Q, block_kv: int = BLOCK_KV,
                    valid_len: int = 0, interpret: bool = False) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) — S and hd already padded by ops.py.

    ``valid_len``: number of real (unpadded) kv positions (0 -> S).
    Returns (B,S,H,hd).
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0
    nq, nk = s // block_q, s // block_kv
    valid_len = valid_len or s
    scale = 1.0  # applied by caller (ops.py) so padding doesn't change scale

    # layout (B,H,S,hd) so blocks are 2D tiles in the (S,hd) plane
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, seq=valid_len,
        causal=causal, window=window, scale=scale, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b_, h_, q_, k_: (b_, h_ // group, k_, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b_, h_, q_, k_: (b_, h_ // group, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
