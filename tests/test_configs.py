"""Per-architecture smoke tests (deliverable f): every assigned architecture,
as a reduced same-family variant, runs one forward and one train step on CPU
with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import (ARCHITECTURES, INPUT_SHAPES, ensemble, get_config,
                           list_architectures, long_context_ok)
from repro.data.pipeline import SyntheticLM
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step

ALL_ARCHS = list_architectures()


def test_registry_complete():
    assert len(ALL_ARCHS) == 10
    families = {get_config(a).family for a in ALL_ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.source


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 16 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    fe = jnp.ones((B, cfg.frontend_tokens, cfg.fdim)) if cfg.frontend_tokens else None
    logits, aux = M.forward(params, cfg, tokens, fe)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt.AdamWConfig(), remat=True))
    batch = SyntheticLM(cfg.vocab_size, 16, task="uniform").batch(2)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.frontend_tokens:
        batch["frontend"] = jnp.zeros((2, cfg.frontend_tokens, cfg.fdim))
    params2, state2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree.leaves(d)) > 0


def test_param_count_matches_init():
    for arch in ALL_ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        # padded embed/head excluded: count real-vocab params analytically
        n_init = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        pad_extra = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
        pad_extra *= 1 if cfg.tie_embeddings else 2
        assert n_init - pad_extra == cfg.param_count(), arch


def test_long_context_applicability():
    ok = {a for a in ALL_ARCHS if long_context_ok(get_config(a))}
    assert ok == {"mamba2-1.3b", "hymba-1.5b", "gemma3-1b", "h2o-danube-1.8b"}


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"] == dict(seq_len=4096, global_batch=256,
                                            kind="train")
    assert INPUT_SHAPES["long_500k"]["seq_len"] == 524288


def test_ensembles():
    assert len(ensemble("ENS1")) == 1
    assert len(ensemble("ENS4")) == 4
    e12 = ensemble("ENS12")
    assert len(e12) == 12
    # heterogeneous members, all with the same class count (combinable)
    assert len({c.name for c in e12}) == 12
    assert len({c.vocab_size for c in e12}) == 1
