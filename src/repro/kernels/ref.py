"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd).  Scale 1/sqrt(hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, valid):
    """q: (B,1,H,hd), k/v: (B,L,KV,hd), valid: (L,) bool -> (B,1,H,hd)."""
    h, hd = q.shape[2], q.shape[3]
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, bmat, cmat, *, chunk=64):
    """The models.ssm chunked implementation is the oracle."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, bmat, cmat, chunk)


def ssd_scan_sequential_ref(x, dt, A, bmat, cmat):
    """Fully sequential SSM recurrence — the ground-truth of ground-truths."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(hstate, inp):
        xt, dtt, bt, ct = inp                       # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * A[None, :])
        hstate = hstate * decay[..., None, None] + \
            jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                                    bmat.swapaxes(0, 1), cmat.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


def ensemble_combine_ref(preds, weights):
    """preds: (M, seg, C), weights: (M,) -> (seg, C)."""
    return jnp.einsum("m,msc->sc", weights.astype(jnp.float32),
                      preds.astype(jnp.float32)).astype(preds.dtype)


def ensemble_accumulate_ref(partial, preds, weights):
    """partial: (seg, C) + weighted member sum — the accumulate variant."""
    return (partial.astype(jnp.float32)
            + ensemble_combine_ref(preds, weights).astype(jnp.float32)
            ).astype(preds.dtype)
