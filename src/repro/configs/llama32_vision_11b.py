"""llama-3.2-vision-11b [vlm] — decoder with cross-attention image layers every
5th layer; the vision tower is the sanctioned frontend stub (input_specs()
supplies precomputed patch embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ATTN, CROSS, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    # every 5th layer cross-attends to the image patch embeddings
    pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    rope_theta=500000.0,
    frontend_tokens=1600,      # 4 tiles x 400 patches, projected by the stub
    frontend_dim=4096,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
