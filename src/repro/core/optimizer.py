"""The allocation-matrix optimizer: Algorithm 1 → Algorithm 2 → disk cache
(paper §II.E: "the best matrix is cached to avoid recomputing it again when
the server will be restarted")."""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocation import (DEFAULT_BATCH_SIZES, AllocationMatrix)
from repro.core.bench import Bench, MemoBench
from repro.core.devices import DeviceSpec
from repro.core.greedy import GreedyTrace, bounded_greedy
from repro.core.worst_fit import worst_fit_decreasing


@dataclass
class OptimizationResult:
    matrix: AllocationMatrix
    wfd_matrix: AllocationMatrix          # Algorithm-1-only (Table I "A1")
    wfd_score: float
    final_score: float
    trace: GreedyTrace
    from_cache: bool = False


class AllocationOptimizer:
    def __init__(self, cfgs: Sequence[ModelConfig], devices: List[DeviceSpec],
                 bench: Bench, *, batch_sizes=DEFAULT_BATCH_SIZES,
                 max_iter: int = 10, max_neighs: int = 100,
                 default_batch_size: int = 8, seq: int = 128,
                 cache_path: Optional[str] = None, seed: int = 0,
                 memoize: bool = True,
                 member_dtypes: Optional[Sequence[Optional[str]]] = None):
        self.cfgs = list(cfgs)
        self.devices = devices
        self.bench = MemoBench(bench) if memoize else bench
        self.batch_sizes = tuple(batch_sizes)
        self.max_iter = max_iter
        self.max_neighs = max_neighs
        self.default_batch_size = default_batch_size
        self.seq = seq
        self.cache_path = cache_path
        self.seed = seed
        # per-member execution dtype: quantized members have ~4x smaller
        # param footprints, so WFD packs them denser (DESIGN.md §14)
        self.member_dtypes = list(member_dtypes) if member_dtypes else None

    # ---- cache --------------------------------------------------------------
    def _cache_key(self) -> str:
        import hashlib
        payload = {"models": [c.name for c in self.cfgs],
                   "devices": [d.key() for d in self.devices],
                   "batch_sizes": self.batch_sizes, "seq": self.seq,
                   "member_dtypes": self.member_dtypes}
        return hashlib.sha256(json.dumps(payload, sort_keys=True).encode()).hexdigest()

    def _load_cached(self) -> Optional[AllocationMatrix]:
        if not self.cache_path or not os.path.exists(self.cache_path):
            return None
        try:
            store = json.load(open(self.cache_path))
            entry = store.get(self._cache_key())
            if entry is None:
                return None
            return AllocationMatrix(self.devices, [c.name for c in self.cfgs],
                                    np.array(entry["A"]))
        except (json.JSONDecodeError, KeyError, ValueError):
            return None

    def _store_cached(self, alloc: AllocationMatrix) -> None:
        if not self.cache_path:
            return
        store = {}
        if os.path.exists(self.cache_path):
            try:
                store = json.load(open(self.cache_path))
            except json.JSONDecodeError:
                store = {}
        store[self._cache_key()] = {"A": alloc.A.tolist()}
        os.makedirs(os.path.dirname(self.cache_path) or ".", exist_ok=True)
        json.dump(store, open(self.cache_path, "w"))

    # ---- the procedure --------------------------------------------------------
    def optimize(self) -> OptimizationResult:
        cached = self._load_cached()
        if cached is not None:
            s = self.bench(cached)
            return OptimizationResult(cached, cached, s, s, GreedyTrace(),
                                      from_cache=True)
        wfd = worst_fit_decreasing(self.cfgs, self.devices,
                                   default_batch_size=self.default_batch_size,
                                   seq=self.seq,
                                   member_dtypes=self.member_dtypes)
        wfd_score = self.bench(wfd)
        best, trace = bounded_greedy(wfd, self.bench, max_iter=self.max_iter,
                                     max_neighs=self.max_neighs,
                                     batch_sizes=self.batch_sizes,
                                     seed=self.seed)
        final_score = self.bench(best)
        self._store_cached(best)
        return OptimizationResult(best, wfd, wfd_score, final_score, trace)
