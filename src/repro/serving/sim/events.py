"""The virtual clock and event heap.

One ``heapq`` of ``(t, seq, fn, args)`` where ``seq`` is a monotone
counter: events at equal timestamps fire in schedule order, so a single
run is a pure function of (trace, seed) — no wall clock, no thread
interleavings.  This is what makes same-seed runs bit-identical
(tests/test_sim.py::test_determinism_bit_identical).
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["EventLoop"]

_INF = float("inf")


class EventLoop:
    __slots__ = ("now", "_heap", "_seq")

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = 0

    def schedule(self, t: float, fn: Callable, *args: Any) -> None:
        """Schedule ``fn(*args)`` at virtual time ``t`` (clamped to now)."""
        if t < self.now:
            t = self.now
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, fn, args))

    def after(self, dt: float, fn: Callable, *args: Any) -> None:
        self.schedule(self.now + dt, fn, *args)

    def peek(self) -> float:
        """Timestamp of the next pending event, +inf if none."""
        return self._heap[0][0] if self._heap else _INF

    def step(self) -> bool:
        """Fire the next event; returns False when the heap is empty."""
        if not self._heap:
            return False
        t, _, fn, args = heapq.heappop(self._heap)
        self.now = t
        fn(*args)
        return True

    def run(self, until: Optional[float] = None) -> None:
        while self._heap:
            if until is not None and self._heap[0][0] > until:
                self.now = until
                return
            self.step()
