"""Shared primitive layers: RMSNorm, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv        # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def embed(tokens: jax.Array, table: jax.Array, scale: bool) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.sqrt(jnp.asarray(table.shape[1], x.dtype))
    return x


def unembed(x: jax.Array, table_or_head: jax.Array, tied: bool) -> jax.Array:
    if tied:   # table: (V, D)
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)
