"""Explicit collective patterns (shard_map) that GSPMD cannot discover.

``flash_decode``: one-token attention against a sequence-sharded KV cache.
Each chip owns an L/n slice of the cache (n = "model" axis): the cache update
touches only the owning chip, attention reads are chip-local, and the online
softmax combines with tiny (B,H)-sized pmax/psum — replacing the involuntary
cache all-gather GSPMD emits for a dynamically-indexed sharded ring buffer
(measured: 2.1 GiB -> ~100 KiB per layer per step on qwen3 decode_32k,
EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes

NEG_INF = -1e30


def flash_decode(mesh, q, k_cache, v_cache, k_new, v_new, pos, *,
                 window: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q: (B,1,H,hd); k_cache/v_cache: (B,L,KV,hd) seq-sharded over "model";
    k_new/v_new: (B,1,KV,hd); pos: scalar int32.

    Returns (out (B,1,H,hd), new_k_cache, new_v_cache).  RoPE/qk-norm must
    already be applied.  Handles full caches (window=0, slot=pos) and SWA
    ring buffers (slot=pos%L) with the same absolute-position masking as the
    single-device path.
    """
    L = k_cache.shape[1]
    n = mesh.shape["model"]
    l_local = L // n
    bax = batch_axes(mesh)
    bspec = bax if len(bax) > 1 else (bax[0] if bax else None)
    cache_spec = P(bspec, "model", None, None)
    rep_spec = P(bspec, None, None, None)

    def local(q, kc, vc, kn, vn, pos):
        idx = jax.lax.axis_index("model")
        lo = idx * l_local
        slot_global = pos % L if window > 0 else pos
        slot = slot_global - lo
        in_range = (slot >= 0) & (slot < l_local)
        slot_c = jnp.clip(slot, 0, l_local - 1)
        kc_up = jax.lax.dynamic_update_index_in_dim(
            kc, kn[:, 0].astype(kc.dtype), slot_c, 1)
        vc_up = jax.lax.dynamic_update_index_in_dim(
            vc, vn[:, 0].astype(vc.dtype), slot_c, 1)
        kc = jnp.where(in_range, kc_up, kc)
        vc = jnp.where(in_range, vc_up, vc)
        # absolute positions of local slots
        gidx = lo + jnp.arange(l_local)
        if window > 0:
            k_pos = pos - ((pos - gidx) % L)
        else:
            k_pos = gidx
        valid = (k_pos <= pos) & (k_pos >= 0)
        if window > 0:
            valid &= k_pos > pos - window
        h = q.shape[2]
        kv = kc.shape[2]
        kx = kc if kv == h else jnp.repeat(kc, h // kv, axis=2)
        vx = vc if kv == h else jnp.repeat(vc, h // kv, axis=2)
        logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                            kx.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        m_loc = logits.max(axis=-1)                      # (B,H,1)
        m = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(logits - m[..., None])
        p = jnp.where(valid[None, None, None, :], p, 0.0)
        l_loc = p.sum(axis=-1)                           # (B,H,1)
        acc = jnp.einsum("bhqs,bshk->bqhk", p, vx.astype(jnp.float32))
        l_tot = jax.lax.psum(l_loc, "model")
        acc = jax.lax.psum(acc, "model")
        out = acc / jnp.maximum(l_tot, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype), kc, vc

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(rep_spec, cache_spec, cache_spec, rep_spec, rep_spec, P()),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_rep=False)
    return fn(q, k_cache, v_cache, k_new, v_new, pos)
