"""HTTP wrapper + adaptive batching (paper §II.A).

A minimal REST layer over the inference system (stdlib only):
  POST /predict   body: {"tokens": [[...], ...]}  -> {"predictions": [[...], ...]}
  GET  /health    -> {"status": "ok", "workers": N}
  GET  /allocation -> the allocation matrix

Adaptive batching: requests are buffered until a full segment accumulates OR
``max_wait_s`` elapses — "triggering prediction before the buffered batch is
full to improve the latency" (paper §I.B).  Note the buffer granularity is
the *segment* size, not any single DNN's batch size (paper §II.A).
"""
from __future__ import annotations

import json
import queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.system import InferenceSystem


class _Pending:
    def __init__(self, x: np.ndarray):
        self.x = x
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None


class AdaptiveBatcher:
    """Buffers requests into segments; flushes on size or timeout."""

    def __init__(self, system: InferenceSystem, *, max_wait_s: float = 0.05,
                 cache=None):
        self.system = system
        self.max_wait_s = max_wait_s
        self.cache = cache                  # optional PredictionCache
        self.q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def submit(self, x: np.ndarray, timeout: float = 120.0) -> np.ndarray:
        p = _Pending(x)
        self.q.put(p)
        if not p.event.wait(timeout):
            raise TimeoutError("request timed out")
        return p.result

    def stop(self):
        self._stop.set()
        self._thread.join(5.0)

    def _run(self):
        target = self.system.segment_size
        while not self._stop.is_set():
            batch: List[_Pending] = []
            count = 0
            deadline = None
            while count < target:
                timeout = None if deadline is None else max(0.0, deadline - time.monotonic())
                try:
                    p = self.q.get(timeout=0.05 if deadline is None else timeout)
                except queue.Empty:
                    if deadline is None:
                        if self._stop.is_set():
                            return
                        continue
                    break                       # adaptive flush on timeout
                if deadline is None:
                    deadline = time.monotonic() + self.max_wait_s
                batch.append(p)
                count += p.x.shape[0]
            if not batch:
                continue
            X = np.concatenate([p.x for p in batch], axis=0)
            try:
                Y = (self.cache.predict_through(self.system, X)
                     if self.cache is not None else self.system.predict(X))
                off = 0
                for p in batch:
                    p.result = Y[off:off + p.x.shape[0]]
                    off += p.x.shape[0]
            except Exception:                   # surface errors to all waiters
                for p in batch:
                    p.result = None
            for p in batch:
                p.event.set()


def serve(system: InferenceSystem, host: str = "127.0.0.1", port: int = 8600,
          *, max_wait_s: float = 0.05,
          cache=None) -> Tuple[ThreadingHTTPServer, AdaptiveBatcher]:
    """Start the HTTP server (returns immediately; server runs on a thread).
    ``cache``: optional serving.request_cache.PredictionCache (paper §I.B)."""
    batcher = AdaptiveBatcher(system, max_wait_s=max_wait_s, cache=cache)

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):              # quiet
            pass

        def _json(self, code: int, payload):
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/health":
                self._json(200, {"status": "ok",
                                 "workers": len(system.workers),
                                 "models": [c.name for c in system.cfgs]})
            elif self.path == "/allocation":
                self._json(200, {"models": system.alloc.model_names,
                                 "A": system.alloc.A.tolist()})
            else:
                self._json(404, {"error": "not found"})

        def do_POST(self):
            if self.path != "/predict":
                self._json(404, {"error": "not found"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(length))
                x = np.asarray(payload["tokens"], np.int32)
                if x.ndim != 2:
                    raise ValueError("tokens must be 2-D (batch, seq)")
                y = batcher.submit(x)
                if y is None:
                    self._json(500, {"error": "prediction failed"})
                    return
                self._json(200, {"predictions": y.tolist()})
            except (KeyError, ValueError, json.JSONDecodeError) as e:
                self._json(400, {"error": str(e)})

    httpd = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    return httpd, batcher
