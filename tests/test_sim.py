"""Discrete-event simulator tests (ISSUE 8, DESIGN.md §12): event-loop
determinism, trace record/replay round-trips, generator reproducibility,
bit-identical same-seed runs, priority isolation and device contention
in-sim, the real control plane (stealing / brownout / EDF / K-tuner)
driven under the virtual clock, the LiveBench forecast-vs-EWMA handoff,
and the live ``InferenceSystem.trace_recorder`` hook."""
import numpy as np
import jax
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.admission import EDFDispatchQueue
from repro.serving.control import BrownoutController, LiveBench
from repro.serving.sim import (DemandForecaster, EventLoop, ServiceModel,
                               SimSystem, WorkerSpec, diurnal_trace,
                               mmpp_trace, poisson_trace,
                               tune_dispatch_ahead)
from repro.serving.trace import (TraceEvent, TraceRecorder, load_trace,
                                 save_trace)

SEQ = 16
GiB = 1024 ** 3


# ---- event loop --------------------------------------------------------------

def test_event_loop_equal_timestamps_fire_in_schedule_order():
    loop = EventLoop()
    fired = []
    loop.schedule(1.0, fired.append, "a")
    loop.schedule(1.0, fired.append, "b")
    loop.schedule(0.5, fired.append, "c")
    loop.schedule(0.0, fired.append, "d")   # in the past once now advances
    loop.run()
    assert fired == ["d", "c", "a", "b"]
    assert loop.now == 1.0
    loop.schedule(0.2, fired.append, "late")   # clamped to now, not dropped
    loop.run()
    assert fired[-1] == "late" and loop.now == 1.0


# ---- trace schema ------------------------------------------------------------

def test_trace_event_json_roundtrip():
    evs = [TraceEvent(t=0.125, rows=64, priority="high", deadline_ms=50.0,
                      members=(0, 2)),
           TraceEvent(t=0.25, rows=1)]   # None deadline / members survive
    for ev in evs:
        assert TraceEvent.from_json(ev.to_json()) == ev


def test_trace_recorder_roundtrip(tmp_path):
    rec = TraceRecorder()
    rec.record(8, t=0.2, priority="normal")
    rec.record(64, t=0.0, priority="high", deadline_ms=10.0, members=[1])
    path = str(tmp_path / "t.jsonl")
    assert rec.save(path) == 2
    evs = load_trace(path)
    assert [e.t for e in evs] == [0.0, 0.2]       # sorted on load
    assert evs[0].members == (1,) and evs[0].deadline_ms == 10.0
    assert evs[1].members is None and evs[1].priority == "normal"


def test_generators_deterministic_and_sorted():
    for gen in (lambda s: poisson_trace(200, rate=100.0, seed=s,
                                        high_fraction=0.3,
                                        members_choices=[(0,), (1,)]),
                lambda s: mmpp_trace(200, seed=s, calm_rate=50.0,
                                     burst_rate=500.0),
                lambda s: diurnal_trace(200, seed=s, rate=100.0,
                                        period_s=1.0)):
        a, b, c = gen(3), gen(3), gen(4)
        assert a == b
        assert a != c
        ts = [e.t for e in a]
        assert ts == sorted(ts)
    tr = diurnal_trace(500, seed=0, rate=1000.0, period_s=0.1)
    assert {e.members for e in tr} == {(0,), (1,)}   # both groups drawn


# ---- core engine -------------------------------------------------------------

def _bulk_sim(**kw):
    svc = kw.pop("svc", ServiceModel.from_delays({0: 500, 1: 500}))
    specs = kw.pop("specs", [WorkerSpec(0, 16), WorkerSpec(1, 16)])
    return SimSystem(svc, specs, segment_size=16, **kw)


def test_sim_underload_completes_everything():
    trace = poisson_trace(500, rate=200.0, seed=1, rows=8,
                          members_choices=[(0,), (1,), (0, 1)])
    sim = _bulk_sim().run(trace)
    r = sim.results()
    assert r["offered"] == 500 and r["completed"] == 500
    assert r["failed"] == 0 and sim.open_requests == 0
    assert 0.0 < r["p99_ms"] and r["throughput_req_per_s"] > 0


def test_sim_determinism_bit_identical():
    trace = mmpp_trace(2000, seed=5, calm_rate=500.0, burst_rate=8000.0,
                       rows=(1, 8, 24), high_fraction=0.2,
                       members_choices=[(0,), (1,), (0, 1)])
    runs = []
    for _ in range(2):
        sim = _bulk_sim(record_events=True).run(trace)
        runs.append((tuple(sim.event_log), sim.results()))
    assert runs[0][0] == runs[1][0]          # bit-identical event log
    assert runs[0][1] == runs[1][1]          # and metrics
    assert len(runs[0][0]) > 0


def test_sim_priority_isolation_under_backlog():
    svc = ServiceModel.from_delays({0: 2000})
    trace = poisson_trace(400, rate=1200.0, seed=2, rows=8,
                          high_fraction=0.15, members_choices=[(0,)])
    sim = SimSystem(svc, [WorkerSpec(0, 8)], segment_size=16,
                    dispatch_ahead=1).run(trace)
    r = sim.results()
    assert r["completed"] == 400
    # saturated bulk backlog: the express path keeps high-priority latency
    # well under the queue-bound normal class
    assert r["hp_p50_ms"] < r["np_p50_ms"] / 2


def test_sim_colocated_workers_time_share_their_device():
    svc = ServiceModel.from_delays({0: 1000, 1: 1000})
    trace = poisson_trace(300, rate=1e6, seed=3, rows=16,
                          members_choices=[(0,), (1,)])

    def makespan(keys):
        sim = SimSystem(svc, [WorkerSpec(0, 16, device_key=keys[0]),
                              WorkerSpec(1, 16, device_key=keys[1])],
                        segment_size=16).run(trace)
        return sim.results()["makespan_s"]

    apart = makespan(("devA", "devB"))
    shared = makespan(("devA", "devA"))   # must serialize: ~2x the makespan
    assert shared > 1.8 * apart


def test_sim_balancer_steals_from_slow_sibling():
    svc = ServiceModel.from_delays({0: 2000})
    trace = poisson_trace(300, rate=2000.0, seed=4, rows=16,
                          members_choices=[(0,)])
    sim = SimSystem(svc, [WorkerSpec(0, 16, speed=1.0),
                          WorkerSpec(0, 16, speed=0.05)], segment_size=16)
    sim.attach_balancer(0.002, threshold=4)
    sim.run(trace)
    r = sim.results()
    assert r["completed"] == 300
    assert sim.timers.counters.get("steals", 0) >= 1


def test_sim_brownout_sheds_infeasible_deadlines():
    svc = ServiceModel.from_delays({0: 5000})
    trace = poisson_trace(1500, rate=10_000.0, seed=6, rows=64,
                          deadline_ms=50.0, members_choices=[(0,)])
    sim = SimSystem(svc, [WorkerSpec(0, 64)], segment_size=64)
    ctrl = BrownoutController(sim, deadline_budget_ms=50.0)   # no .start()
    sim.add_control(ctrl.interval_s, lambda s: ctrl.step())
    sim.run(trace)
    r = sim.results()
    assert r["shed"] > 0                       # cost-aware admission engaged
    # every offered request resolves: served, typed-shed, or expired-dropped
    assert r["completed"] + r["shed"] + r["failed"] == r["offered"]
    assert r["completed"] > 0


def test_sim_edf_clears_deadlines_fifo_misses():
    svc = ServiceModel.from_delays({0: 2000})
    events = []
    for b in range(10):
        t = b * 0.012
        for i in range(4):
            events.append(TraceEvent(t=t + i * 1e-5, rows=64,
                                     deadline_ms=7.0 if i >= 2 else 400.0,
                                     members=(0,)))
    misses = {}
    for name, kw in (("fifo", {}), ("edf", {"queue_cls": EDFDispatchQueue})):
        sim = SimSystem(svc, [WorkerSpec(0, 64)], segment_size=64,
                        dispatch_ahead=1, max_wait_us=100, **kw)
        sim.run(events)
        misses[name] = sim.results()["deadline_misses"]
    assert misses["fifo"] > 0
    assert misses["edf"] == 0


def test_tuner_reproduces_dispatch_ahead_default():
    svc = ServiceModel.from_delays({0: 1000}, dispatch_overhead_s=2e-4)
    trace = poisson_trace(200, rate=1e6, seed=13, rows=64,
                          members_choices=[(0,)])
    out = tune_dispatch_ahead(
        lambda k: SimSystem(svc, [WorkerSpec(0, 8)], segment_size=64,
                            dispatch_ahead=k, max_wait_us=100),
        trace, ks=(1, 4, 16, 32))
    assert out["recommended"] == 16
    thr = {k: v["throughput_rows_per_s"] for k, v in out["per_k"].items()}
    assert thr[16] > thr[1]                    # overhead amortization is real


# ---- service model -----------------------------------------------------------

def test_service_model_fit_paths():
    flat = ServiceModel.from_delays({0: 1000})
    assert flat.chunk_time(0, 8) == pytest.approx(1e-3)
    assert flat.chunk_time(0, 64) == pytest.approx(1e-3)   # bucket-flat
    snap = {"latency_ewma_s": {"m0|cpu:0|b16": 0.002, "m0|cpu:1|b16": 0.004,
                               "m1|cpu:0|b8": 0.001}}
    fit = ServiceModel.from_livebench(snap)
    assert fit.chunk_time(0, 16) == pytest.approx(0.003)   # device-averaged
    assert fit.chunk_time(0, 32) == pytest.approx(0.006)   # row-scaled
    assert fit.members() == (0, 1)
    with pytest.raises(ValueError):
        ServiceModel.from_livebench({"latency_ewma_s": {}})


# ---- forecasting -------------------------------------------------------------

def test_forecaster_extrapolates_linear_trend():
    fc = DemandForecaster(2, bin_s=0.1, trend_bins=4)
    # member 0's share climbs 0.2 -> 0.5 over closed bins; the trend must
    # put the lead-horizon prediction above the last observed share
    for i, share in enumerate((0.2, 0.3, 0.4, 0.5)):
        for _ in range(int(share * 100)):
            fc.observe(i * 0.1, [0], 1)
        for _ in range(int((1 - share) * 100)):
            fc.observe(i * 0.1, [1], 1)
    fc.observe(0.4, [0], 1)                    # close the last bin
    pred = fc.predict_shares(lead_s=0.2)
    assert pred[0] > 0.55
    assert pred.sum() == pytest.approx(1.0)
    cold = DemandForecaster(3, bin_s=0.1)
    assert cold.predict_shares(0.1) == pytest.approx(np.full(3, 1 / 3))


def test_livebench_forecast_fresh_then_stale_handoff():
    cfgs = ensemble("ENS4")[:2]
    live = LiveBench(cfgs, seq=SEQ)
    t = [0.0]
    live.clock = lambda: t[0]                  # virtual time, as in-sim
    for _ in range(50):
        live.note_request([0], 8)              # EWMA: all demand on m0
    fc = DemandForecaster(2, bin_s=0.1, trend_bins=2)
    for i in range(3):                         # forecaster: all demand on m1
        fc.observe(i * 0.1, [1], 8)
    fc.feed(live, lead_s=0.1, ttl_s=0.5)
    assert live.forecast_fresh()
    assert live.demand_shares()[1] > 0.9       # fresh: forecast wins
    t[0] += 1.0                                # TTL expires on virtual clock
    assert not live.forecast_fresh()
    assert live.demand_shares()[0] > 0.9       # stale: EWMA fallback


# ---- the live recorder hook (satellite of ISSUE 8) ---------------------------

@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def test_inference_system_records_offered_trace(ens2, tmp_path):
    from repro.serving.segments import PredictOptions
    from repro.serving.system import InferenceSystem
    cfgs, params = ens2
    devs = host_cpus(1, memory_bytes=8 * GiB)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs],
                             np.array([[16, 16]]))
    system = InferenceSystem(cfgs, params, alloc, max_seq=SEQ)
    rec = TraceRecorder()
    system.trace_recorder = rec
    try:
        X = np.zeros((3, SEQ), np.int32)
        system.predict(X, timeout=60.0)
        system.predict(X[:1], timeout=60.0,
                       options=PredictOptions(priority="high",
                                              deadline_ms=5e3, members=[1]))
    finally:
        system.shutdown()
    evs = rec.events()
    assert [(e.rows, e.priority, e.members) for e in evs] == \
        [(3, "normal", (0, 1)), (1, "high", (1,))]
    assert evs[1].deadline_ms == 5e3
    assert evs[0].t == 0.0 and evs[1].t >= 0.0
    path = str(tmp_path / "live.jsonl")
    save_trace(path, evs)
    sim = SimSystem(ServiceModel.from_delays({0: 100, 1: 100}),
                    [WorkerSpec(0, 16), WorkerSpec(1, 16)],
                    segment_size=16).run(load_trace(path))
    assert sim.results()["completed"] == 2     # recorded traces replay as-is
