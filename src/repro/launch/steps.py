"""Lowerable step functions + ShapeDtypeStruct input specs for the dry-run.

Three step kinds, chosen by the input shape's ``kind``:
  * train   — full AdamW train_step (remat'd scan over layers)
  * prefill — prompt pass returning last-token logits + materialized cache
  * decode  — ONE new token against a seq_len KV cache (serve_step)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.configs.base import ModelConfig
from repro.models import cache as cache_mod
from repro.models import decode_step as model_decode
from repro.models import forward, prefill as model_prefill
from repro.models.transformer import param_struct
from repro.parallel import sharding as shd
from repro.training import optimizer as opt
from repro.training.train_loop import loss_fn


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape_name: str, *,
                param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """All step inputs for (cfg, shape) as ShapeDtypeStructs."""
    sh = INPUT_SHAPES[shape_name]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    specs: Dict[str, Any] = {"params": param_struct(cfg, param_dtype)}
    if kind == "train":
        specs["opt_state"] = opt_state_struct(specs["params"])
        specs["batch"] = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.frontend_tokens:
            specs["batch"]["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.fdim), param_dtype)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.frontend_tokens:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.fdim), param_dtype)
    elif kind == "decode":
        from repro import runtime_flags
        specs["cache"] = cache_mod.cache_struct(
            cfg, b, s, param_dtype,
            quantized=bool(runtime_flags.SHARDING_OPTS.get("kv_quant")))
        specs["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        raise ValueError(kind)
    return specs


def opt_state_struct(params_struct) -> opt.AdamWState:
    f32 = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return opt.AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                          f32(params_struct), f32(params_struct))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, *, remat: bool = True):
    ocfg = opt.AdamWConfig()

    def train_step(params, opt_state, batch):
        def fwd(p):
            return loss_fn(p, cfg, batch["tokens"], batch["labels"],
                           batch.get("frontend"), remat=remat)
        (loss, metrics), grads = jax.value_and_grad(fwd, has_aux=True)(params)
        params, opt_state, om = opt.apply(ocfg, params, grads, opt_state)
        return params, opt_state, dict(metrics, loss=loss, **om)

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int):
    if cfg.frontend_tokens:
        def step(params, tokens, frontend):
            return model_prefill(params, cfg, tokens, max_len, frontend)
    else:
        def step(params, tokens):
            return model_prefill(params, cfg, tokens, max_len)
    return step


def build_decode_step(cfg: ModelConfig):
    def step(params, cache, token, pos):
        return model_decode(params, cfg, cache, token, pos)
    return step


# ---------------------------------------------------------------------------
# sharded jit: the (arch x shape x mesh) lowering used by dryrun/roofline
# ---------------------------------------------------------------------------
def lower_step(cfg: ModelConfig, shape_name: str, mesh, *,
               param_dtype=jnp.bfloat16, remat: bool = True):
    """Returns (lowered, kind).  ``lowered.compile()`` is the dry-run proof."""
    sh = INPUT_SHAPES[shape_name]
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    specs = input_specs(cfg, shape_name, param_dtype=param_dtype)
    pshard = shd.param_shardings(cfg, mesh)
    repl = NamedSharding(mesh, P())

    if kind == "train":
        step = build_train_step(cfg, remat=remat)
        oshard = opt.AdamWState(repl, pshard, pshard)
        bshard = {
            "tokens": NamedSharding(mesh, shd.batch_spec(mesh, b, 2)),
            "labels": NamedSharding(mesh, shd.batch_spec(mesh, b, 2)),
        }
        if cfg.frontend_tokens:
            bshard["frontend"] = NamedSharding(mesh, shd.batch_spec(mesh, b, 3))
        metr = {k: repl for k in ("ce", "aux", "loss", "grad_norm", "lr")}
        jfn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard, metr))
        with mesh:
            lowered = jfn.lower(specs["params"], specs["opt_state"], specs["batch"])
        return lowered, kind

    if kind == "prefill":
        step = build_prefill_step(cfg, max_len=s)
        tshard = NamedSharding(mesh, shd.batch_spec(mesh, b, 2))
        cshard = shd.to_named(shd.cache_specs(cfg, mesh, b, s), mesh)
        lshard = NamedSharding(mesh, shd.batch_spec(mesh, b, 2))
        args = [specs["params"], specs["tokens"]]
        ins = [pshard, tshard]
        if cfg.frontend_tokens:
            args.append(specs["frontend"])
            ins.append(NamedSharding(mesh, shd.batch_spec(mesh, b, 3)))
        jfn = jax.jit(step, in_shardings=tuple(ins),
                      out_shardings=(lshard, cshard))
        with mesh:
            lowered = jfn.lower(*args)
        return lowered, kind

    if kind == "decode":
        step = build_decode_step(cfg)
        cshard = shd.to_named(shd.cache_specs(cfg, mesh, b, s), mesh)
        tokshard = NamedSharding(mesh, shd.batch_spec(mesh, b, 2))
        lshard = NamedSharding(mesh, shd.batch_spec(mesh, b, 2))
        jfn = jax.jit(step, in_shardings=(pshard, cshard, tokshard, repl),
                      out_shardings=(lshard, cshard))
        with mesh:
            lowered = jfn.lower(specs["params"], specs["cache"],
                                specs["token"], specs["pos"])
        return lowered, kind

    raise ValueError(kind)
