"""The allocation matrix — the paper's central data structure (§II.B).

``A`` is a (D devices × M models) integer matrix.  ``A[d, m] == 0`` means no
worker for model m on device d; any other value is that worker's batch size.
Several non-zeros in a row = co-localization; several non-zeros in a column =
data-parallelism.  All-zero columns are illegal (every ensemble member must be
served); all-zero rows are idle devices (legal).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.devices import DeviceSpec

DEFAULT_BATCH_SIZES = (8, 16, 32, 64, 128)     # §III "possible batch size values"


@dataclass
class AllocationMatrix:
    devices: List[DeviceSpec]
    model_names: List[str]
    A: np.ndarray                                 # (D, M) int

    def __post_init__(self):
        self.A = np.asarray(self.A, dtype=np.int64)
        if self.A.shape != (len(self.devices), len(self.model_names)):
            raise ValueError(f"A shape {self.A.shape} != "
                             f"({len(self.devices)}, {len(self.model_names)})")

    # ---- validity ---------------------------------------------------------
    def is_valid(self) -> bool:
        """No all-zero columns; non-negative entries."""
        if (self.A < 0).any():
            return False
        return bool((self.A.sum(axis=0) > 0).all())

    def validate(self) -> None:
        if not self.is_valid():
            empty = [self.model_names[m] for m in
                     np.where(self.A.sum(axis=0) == 0)[0]]
            raise ValueError(f"invalid allocation: unserved models {empty}")

    # ---- structure queries --------------------------------------------------
    def workers(self) -> List[Tuple[int, int, int]]:
        """All (device_idx, model_idx, batch_size) workers."""
        d_idx, m_idx = np.nonzero(self.A)
        return [(int(d), int(m), int(self.A[d, m])) for d, m in zip(d_idx, m_idx)]

    def colocated(self, d: int) -> List[int]:
        return [int(m) for m in np.nonzero(self.A[d])[0]]

    def instances(self, m: int) -> List[int]:
        return [int(d) for d in np.nonzero(self.A[:, m])[0]]

    def num_workers(self) -> int:
        return int((self.A > 0).sum())

    # ---- the decision space (paper Eq. 1 / Eq. 2) ---------------------------
    @staticmethod
    def total_matrices(D: int, M: int, B: int) -> int:
        """Eq. 1: ((B+1)^D - 1)^M."""
        return ((B + 1) ** D - 1) ** M

    def total_neighbors(self, batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES) -> int:
        """Eq. 2: (B+1)*(D*M) - F, with F the forbidden (invalid) moves."""
        B = len(batch_sizes)
        D, M = self.A.shape
        forbidden = 0
        for d, m in itertools.product(range(D), range(M)):
            if self.A[d, m] > 0 and len(self.instances(m)) == 1:
                forbidden += 1          # zeroing the sole instance is illegal
        return (B + 1) * D * M - forbidden

    def neighbors(self, batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES
                  ) -> Iterator["AllocationMatrix"]:
        """All valid matrices differing from self in exactly one element."""
        D, M = self.A.shape
        for d in range(D):
            for m in range(M):
                cur = self.A[d, m]
                for val in (0, *batch_sizes):
                    if val == cur:
                        continue
                    new = self.A.copy()
                    new[d, m] = val
                    cand = AllocationMatrix(self.devices, self.model_names, new)
                    if cand.is_valid():
                        yield cand

    # ---- identity / serialization -------------------------------------------
    def key(self) -> str:
        payload = {
            "devices": [d.key() for d in self.devices],
            "models": list(self.model_names),
            "A": self.A.tolist(),
        }
        return hashlib.sha256(json.dumps(payload, sort_keys=True).encode()).hexdigest()

    def to_json(self) -> str:
        return json.dumps({"models": self.model_names, "A": self.A.tolist(),
                           "devices": [d.name for d in self.devices]})

    def copy(self) -> "AllocationMatrix":
        return AllocationMatrix(self.devices, self.model_names, self.A.copy())

    def pretty(self) -> str:
        """Table II-style rendering."""
        w = max(len(n) for n in self.model_names) if self.model_names else 4
        w = min(w, 24)
        head = " " * 8 + " ".join(f"{n[:w]:>{w}}" for n in self.model_names)
        rows = [head]
        for d, dev in enumerate(self.devices):
            rows.append(f"{dev.name:>7} " +
                        " ".join(f"{int(v):>{w}}" for v in self.A[d]))
        return "\n".join(rows)


def zeros(devices: List[DeviceSpec], model_names: List[str]) -> AllocationMatrix:
    return AllocationMatrix(devices, model_names,
                            np.zeros((len(devices), len(model_names)), np.int64))
