"""End-to-end inference-system tests: ensemble prediction correctness vs the
oracle, combination rules, co-localization/data-parallelism, the paper's
sentinel protocol, and Benchmark Mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.models as M
from repro.configs import ensemble, get_config
from repro.core import AllocationMatrix, host_cpus
from repro.serving.system import InferenceSystem
from repro.serving import segments as seg

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def oracle(cfgs, params, X, weights=None):
    w = weights if weights is not None else [1 / len(cfgs)] * len(cfgs)
    out = np.zeros((X.shape[0], cfgs[0].vocab_size), np.float32)
    for i, (c, p) in enumerate(zip(cfgs, params)):
        fe = jnp.zeros((X.shape[0], c.frontend_tokens, c.fdim)) \
            if c.frontend_tokens else None
        lg, _ = M.forward(p, c, jnp.asarray(X), fe)
        out += np.asarray(lg[:, -1, :c.vocab_size]) * w[i]
    return out


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


def test_predict_matches_oracle(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(0).integers(0, 512, (70, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 16]]), segment_size=32) as s:
        Y = s.predict(X)
    np.testing.assert_allclose(Y, oracle(cfgs, params, X), atol=2e-5)


def test_data_parallel_and_colocation(ens2):
    """2 instances of model 0 (data-parallel) + co-location on device 0."""
    cfgs, params = ens2
    X = np.random.default_rng(1).integers(0, 512, (100, SEQ)).astype(np.int32)
    A = np.array([[8, 8],
                  [16, 0]])
    with make_system(cfgs, params, A, segment_size=16) as s:
        assert len(s.workers) == 3
        Y = s.predict(X)
    np.testing.assert_allclose(Y, oracle(cfgs, params, X), atol=2e-5)


def test_weighted_and_vote_combine(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(2).integers(0, 512, (20, SEQ)).astype(np.int32)
    w = np.array([0.8, 0.2], np.float32)
    with make_system(cfgs, params, np.array([[8, 8]]), combine="weighted",
                     weights=w, segment_size=16) as s:
        Y = s.predict(X)
    np.testing.assert_allclose(Y, oracle(cfgs, params, X, w), atol=2e-5)

    with make_system(cfgs, params, np.array([[8, 8]]), combine="vote",
                     segment_size=16) as s:
        Yv = s.predict(X)
    # votes sum to 1 per row across classes
    np.testing.assert_allclose(Yv.sum(axis=1), 1.0, atol=1e-6)


def test_pallas_combine_matches_mean(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(3).integers(0, 512, (40, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        Y1 = s.predict(X)
    with make_system(cfgs, params, np.array([[8, 8]]), combine="pallas",
                     segment_size=16) as s:
        Y2 = s.predict(X)
    np.testing.assert_allclose(Y1, Y2, atol=1e-5)


def test_benchmark_mode_returns_throughput(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(4).integers(0, 512, (64, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32) as s:
        Y, thr = s.benchmark(X)
    assert thr > 0
    assert Y.shape == (64, 512)


def test_fake_mode_measures_overhead(ens2):
    """§IV.A: fake predictors return zeros; the pipeline overhead is tiny."""
    cfgs, params = ens2
    X = np.random.default_rng(5).integers(0, 512, (256, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), fake=True,
                     segment_size=64) as s:
        Y, thr = s.benchmark(X)
    assert np.all(Y == 0)
    assert thr > 1000            # >1k samples/s through the fake pipeline


def test_ready_sentinel_protocol(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        assert s.accumulator.ready_count == len(s.workers)
        assert s.accumulator.all_ready.is_set()


def test_mismatched_classes_rejected():
    import dataclasses
    cfgs = ensemble("ENS4")[:2]
    cfgs = [cfgs[0], dataclasses.replace(cfgs[1], vocab_size=256)]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    with pytest.raises(ValueError, match="class count"):
        make_system(cfgs, params, np.array([[8, 8]]))


def test_segment_math():
    assert seg.num_segments(300, 128) == 3
    assert seg.start(2, 128) == 256
    assert seg.end(2, 128, 300) == 300       # the paper's 300-image example
    assert seg.end(0, 128, 300) == 128


def test_ensemble_selection_subset(ens2):
    """paper §I.B "ensemble selection": the client picks a member subset."""
    cfgs, params = ens2
    X = np.random.default_rng(7).integers(0, 512, (20, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        y_all = s.predict(X)
        y_m0 = s.predict(X, members=[0])
        y_m1 = s.predict(X, members=[1])
    np.testing.assert_allclose(y_m0, oracle(cfgs[:1], params[:1], X), atol=2e-5)
    np.testing.assert_allclose(y_m1, oracle(cfgs[1:], params[1:], X), atol=2e-5)
    np.testing.assert_allclose(0.5 * (y_m0 + y_m1), y_all, atol=2e-5)
