"""granite-moe-3b-a800m [moe] — 40 experts, top-8, narrow experts (d_ff=512).
[hf:ibm-granite/granite-3.0-1b-a400m-base family]"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                      # per-expert hidden size
    vocab_size=49155,
    pattern=(ATTN,),
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
    rope_theta=10000.0,
    tie_embeddings=True,
    vocab_pad_to=2048,             # 49155 -> 51200 allocation-friendly on 16-way meshes
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
