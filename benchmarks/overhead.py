"""Paper §IV.A: inference-system overhead, measured by swapping every
predictor for a fake zero-returning one (the accumulator still gathers and
combines segments).  The paper reports <=2% of total inference time."""
from __future__ import annotations

import numpy as np

from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus

GiB = 1024 ** 3


def run(csv=True, n_samples=512, seq=16):
    import jax
    import repro.models as M
    from repro.serving.system import InferenceSystem
    cfgs = ensemble("ENS4")
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    devs = host_cpus(2, memory_bytes=4 * GiB)
    A = np.array([[8, 0, 16, 8],
                  [8, 16, 0, 0]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    X = np.random.default_rng(0).integers(0, 512, (n_samples, seq)).astype(np.int32)

    with InferenceSystem(cfgs, params, alloc, segment_size=128,
                         max_seq=seq, fake=True) as fake_sys:
        _, fake_thr = fake_sys.benchmark(X, repeats=3)
        fake_stages = fake_sys.stage_timings()
    with InferenceSystem(cfgs, params, alloc, segment_size=128,
                         max_seq=seq) as real_sys:
        _, real_thr = real_sys.benchmark(X)
        real_stages = real_sys.stage_timings()

    fake_time = n_samples / fake_thr          # pipeline-only time
    real_time = n_samples / real_thr
    overhead_pct = 100.0 * fake_time / real_time
    if csv:
        print("overhead:metric,value")
        print(f"overhead:pipeline_time_s,{fake_time:.4f}")
        print(f"overhead:total_time_s,{real_time:.4f}")
        print(f"overhead:overhead_pct,{overhead_pct:.2f}")
        for label, stages in [("pipeline", fake_stages), ("total", real_stages)]:
            for stage, t in stages.items():
                print(f"overhead:{label}.{stage}_s,{t['total_s']:.4f}")
    return {"pipeline_s": fake_time, "total_s": real_time,
            "overhead_pct": overhead_pct,
            "pipeline_stage_timings": fake_stages,
            "total_stage_timings": real_stages}


if __name__ == "__main__":
    run()
