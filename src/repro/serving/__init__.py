"""The asynchronous inference system (paper §II): segment broadcaster,
worker pool, prediction accumulator, the EnsembleClient request facade and
the HTTP wrapper."""
from repro.serving.accumulator import PredictionAccumulator, RequestHandle
from repro.serving.admission import (AdmissionBudget, AdmissionQueue,
                                     DispatchQueue, chunk_level)
from repro.serving.client import ClientHandle, EnsembleClient
from repro.serving.combiner import DeviceCombiner
from repro.serving.faults import FaultPlan, FaultSpec, InjectedFault
from repro.serving.metrics import StageTimers
from repro.serving.request_cache import PredictionCache
from repro.serving.segments import (DEFAULT_SEGMENT_SIZE, PRIORITY_HIGH,
                                    PRIORITY_NORMAL, ChunkDesc,
                                    DeadlineExceeded, MemberUnavailable,
                                    Message, Overloaded, PredictOptions,
                                    Request, RequestCancelled,
                                    RetriesExhausted, ServingUnavailable,
                                    SlotRef, WorkerCrashed)
from repro.serving.server import AdaptiveBatcher, serve
from repro.serving.system import InferenceSystem
from repro.serving.tracing import FlightRecorder, Tracer
from repro.serving.worker import Worker, bucket_for, make_predict_fn
from repro.serving.control import (BrownoutController, LiveBench,
                                   ReconfigController, Supervisor)

__all__ = ["InferenceSystem", "Worker", "make_predict_fn", "bucket_for",
           "Message", "Request", "RequestHandle", "PredictionAccumulator",
           "DeviceCombiner", "StageTimers", "AdaptiveBatcher", "serve",
           "DEFAULT_SEGMENT_SIZE", "PredictOptions", "EnsembleClient",
           "ClientHandle", "AdmissionQueue", "DispatchQueue", "chunk_level",
           "ChunkDesc", "SlotRef", "PredictionCache",
           "DeadlineExceeded", "RequestCancelled", "PRIORITY_HIGH",
           "PRIORITY_NORMAL", "LiveBench", "ReconfigController",
           "FaultPlan", "FaultSpec", "InjectedFault", "Supervisor",
           "ServingUnavailable", "WorkerCrashed", "MemberUnavailable",
           "RetriesExhausted", "Overloaded", "AdmissionBudget",
           "BrownoutController", "Tracer", "FlightRecorder"]
