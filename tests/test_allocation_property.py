"""Hypothesis property tests on the allocation-system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ensemble
from repro.core import (AllocationMatrix, AnalyticBench, simulated_gpus,
                        worst_fit_decreasing, zeros)
from repro.core.allocation import DEFAULT_BATCH_SIZES
from repro.core import memory as mem
from repro.core.worst_fit import AllocationError

GiB = 1024 ** 3
ENS = ensemble("ENS4")
BATCHES = (0,) + DEFAULT_BATCH_SIZES


@st.composite
def matrices(draw, max_d=5, models=4):
    d = draw(st.integers(1, max_d))
    a = np.array([[draw(st.sampled_from(BATCHES)) for _ in range(models)]
                  for _ in range(d)])
    return AllocationMatrix(simulated_gpus(d), [c.name for c in ENS[:models]], a)


@given(matrices())
@settings(max_examples=60, deadline=None)
def test_neighbors_preserve_validity(alloc):
    """Every enumerated neighbour of a valid matrix is valid and one-step."""
    if not alloc.is_valid():
        return
    for n in alloc.neighbors(DEFAULT_BATCH_SIZES):
        assert n.is_valid()
        assert (n.A != alloc.A).sum() == 1


@given(matrices())
@settings(max_examples=60, deadline=None)
def test_key_is_canonical(alloc):
    """Equal matrices hash equal; single-cell edits change the key."""
    same = AllocationMatrix(alloc.devices, alloc.model_names, alloc.A.copy())
    assert alloc.key() == same.key()
    edited = alloc.copy()
    edited.A[0, 0] = 8 if edited.A[0, 0] != 8 else 16
    assert edited.key() != alloc.key()


@given(matrices())
@settings(max_examples=40, deadline=None)
def test_bench_zero_iff_invalid_or_oom(alloc):
    """The bench returns 0 exactly for invalid/infeasible matrices, and a
    positive throughput otherwise (paper's convention)."""
    bench = AnalyticBench(ENS, seq=128)
    score = bench(alloc)
    feasible = alloc.is_valid() and mem.fit_mem(alloc, ENS, 128,
                                                bench.dtype_bytes)
    assert (score > 0) == feasible


@given(st.integers(1, 8), st.integers(1, 60))
@settings(max_examples=30, deadline=None)
def test_worst_fit_feasible_or_error(n_gpus, mem_hundred_mib):
    """Algorithm 1 either returns a feasible full placement or raises."""
    devs = simulated_gpus(n_gpus, memory_bytes=mem_hundred_mib * 100 * 1024 ** 2)
    try:
        alloc = worst_fit_decreasing(ENS, devs)
    except AllocationError:
        return
    assert alloc.is_valid()
    assert mem.fit_mem(alloc, ENS, 128)
    assert alloc.num_workers() == len(ENS)       # exactly one worker per model


@given(st.integers(2, 10), st.integers(2, 12), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_eq1_grows_with_dims(d, m, b):
    t = AllocationMatrix.total_matrices(d, m, b)
    assert t > AllocationMatrix.total_matrices(d - 1, m, b)
    assert t > AllocationMatrix.total_matrices(d, m - 1, b)


@given(matrices())
@settings(max_examples=40, deadline=None)
def test_device_usage_additive(alloc):
    """Memory usage decomposes as the sum over workers."""
    usage = mem.device_usage(alloc, ENS, 128)
    expect = [0] * len(alloc.devices)
    for d, m, b in alloc.workers():
        expect[d] += mem.worker_bytes(ENS[m], b, 128)
    assert usage == expect
