"""Checkpointing: pytree <-> npz with structure manifest (pure numpy/JSON).

Layout: <dir>/step_<N>/arrays.npz + manifest.json; ``latest`` tracked by a
top-level JSON.  Works for params and optimizer state alike (any pytree of
arrays + scalars).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out, treedef


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic save; prunes to the newest ``keep`` checkpoints."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": arr for i, (_, arr) in enumerate(flat)})
    json.dump({"keys": [k for k, _ in flat], "step": step},
              open(os.path.join(tmp, "manifest.json"), "w"))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    json.dump({"latest": step}, open(os.path.join(directory, "LATEST.json"), "w"))
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]) for d in os.listdir(directory)
         if d.startswith("step_")), reverse=True)
    for s in steps[keep:]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))["latest"]


def restore(directory: str, like, step: Optional[int] = None):
    """Restore into the structure of ``like`` (a template pytree)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = [data[f"a{i}"] for i in range(len(manifest["keys"]))]
    flat_like, treedef = _flatten_with_paths(like)
    keys_like = [k for k, _ in flat_like]
    if keys_like != manifest["keys"]:
        raise ValueError("checkpoint structure mismatch:\n"
                         f"  ckpt: {manifest['keys'][:5]}...\n"
                         f"  tmpl: {keys_like[:5]}...")
    leaves_template = jax.tree_util.tree_leaves(like)
    restored = [np.asarray(a, dtype=np.asarray(t).dtype)
                for a, t in zip(arrays, leaves_template)]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), restored)
