"""A worker: one model instance pinned to one device at one batch size.

Faithful to paper Fig. 2 — three asynchronous threads per worker:
  * the *batcher* coalesces incoming segment rows into padded batches,
  * the *predictor* owns the params on its device and runs the jitted step,
  * the *prediction sender* scatters batch outputs back to their segments
    and forwards them (device partial or {s, m, P} message).

Hardware adaptation (DESIGN.md §2): the paper uses one OS process per worker
(TF1 sessions hold the GIL); with JAX, XLA executions release the GIL and
dispatch is asynchronous, so threads + per-worker queues give the same
overlap without IPC serialization overhead.

Coalescing scheduler (DESIGN.md §3): the paper's batching process forms
batches strictly within one (request, segment) pair, so heavy traffic of
many small requests runs nothing but padded remainder buckets.  Here the
batcher drains its input queue and packs rows from *multiple* in-flight
requests/segments into full compiled batches:

  * the unit moved through the pipeline is a **ring slot** spanning
    ``ceil(segment/batch)`` compiled batches, plus a **scatter descriptor**
    — a list of :class:`~repro.serving.segments.Span` entries mapping slot
    row-ranges back to (request, segment, segment-row) coordinates.  Spans
    never cross a compiled-batch boundary, so each span belongs to exactly
    one predictor chunk;
  * a full slot flushes immediately; a partial slot lingers at most
    ``max_wait_us`` for more rows (bounded latency), and ``SHUTDOWN`` /
    ``FLUSH`` (quiesce) force an immediate flush;
  * a flushed slot is cut into full compiled batches plus a short remainder
    padded to the next **power-of-two bucket** (not the full compiled batch)
    — one jitted callable serves every bucket, with jit's shape cache
    bounding compilations to ~log2(batch) entries, and input buffers are
    donated on accelerators so XLA can reuse them;
  * ``coalesce=False`` restores the PR-1 one-item-at-a-time batching (each
    (request, segment) flushes its own slot) as a measurement baseline;
  * slots come from a **preallocated ring** (free-list backpressure bounds
    in-flight memory); a slot is recycled only after the predictor's output
    is materialized — on CPU ``device_put`` may alias host memory, so early
    reuse would corrupt an in-flight batch.  Mismatched-seq requests
    (request width != compiled ring width) draw buffers from a small
    per-width side pool instead of allocating per slot;
  * the sender reassembles each segment from its spans (all of a segment's
    spans pass through one sender in order) and forwards ONE contribution
    per (request, segment) — per-span forwarding would multiply
    combiner/accumulator traffic by chunks-per-segment;
  * per-stage wall-clock counters (metrics.StageTimers) instrument the
    batcher wait, batch fill, predict dispatch, and device sync/transfer;
    padding counters (``rows_valid`` / ``rows_dispatched``) and the
    ``queue_depth`` gauge expose coalescing efficiency.

Request-API admission (DESIGN.md §7): the input queue is a two-level
:class:`~repro.serving.admission.AdmissionQueue` — high-priority descriptors
drain before normal ones, and packing a high-priority request's rows
*preempts the linger* (the open slot's deadline collapses to "flush as soon
as the queue runs dry") so a latency-sensitive request never waits out
``max_wait_us`` behind its own batch.  A descriptor whose request is past
its deadline or cancelled is dropped instead of packed: the batcher posts
``Message(DROPPED, ...)`` and the accumulator fails the request, so expired
work never occupies ring slots or device time.  With ``linger="adaptive"``
the linger budget scales down with the queue backlog (deep queue → flush
immediately, idle queue → full ``max_wait_us``; ROADMAP item b).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.devices import DeviceSpec
from repro.kernels.ops import pow2_clamp
from repro.serving import segments as seg
from repro.serving.metrics import StageTimers
from repro.serving.segments import (FLUSH, FlushBarrier, Message, Request,
                                    SHUTDOWN, Span)

MIN_BUCKET = 8
RING_SLOTS = 4          # in-flight slot bound per worker
ALT_POOL_CAP = 4        # pooled mismatched-seq buffers per width
ADAPTIVE_DEPTH = 8      # linger="adaptive": backlog at which linger hits 0


def bucket_for(n: int, batch_size: int) -> int:
    """Compiled batch shape for an ``n``-row chunk: the full batch size, or
    the next power of two >= n (min 8) for remainder chunks."""
    if n >= batch_size:
        return batch_size
    return pow2_clamp(n, MIN_BUCKET, batch_size)


def make_predict_fn(cfg: ModelConfig, use_kernel: bool = False,
                    donate: bool = False) -> Callable:
    """Classification-style serving fn: tokens (b,S) -> last-token class
    scores (b, C) with C = the unpadded vocab (the paper's f(x)->y).
    ``donate`` hands the token buffer to XLA for reuse (accelerators only —
    CPU ignores donation and would warn on every compile)."""
    from repro.models import forward

    def predict(params, tokens, frontend):
        logits, _ = forward(params, cfg, tokens, frontend, use_kernel=use_kernel)
        return logits[:, -1, :cfg.vocab_size]

    return jax.jit(predict, donate_argnums=(1,) if donate else ())


class _OpenBatch:
    """The batcher's in-progress coalesced batch."""
    __slots__ = ("slot", "buf", "width", "fill", "spans", "deadline")

    def __init__(self, slot, buf, width: int, deadline: float):
        self.slot = slot             # ring index, or None (side-pool buffer)
        self.buf = buf
        self.width = width
        self.fill = 0
        self.spans: List[Span] = []
        self.deadline = deadline     # linger expiry (perf_counter seconds)


class Worker:
    def __init__(self, worker_id: str, cfg: ModelConfig, params,
                 device: DeviceSpec, batch_size: int,
                 input_queue: "queue.Queue",
                 prediction_queue: "queue.Queue[Message]",
                 model_idx: int, max_seq: int, segment_size: int,
                 *, fake: bool = False, frontend: Optional[np.ndarray] = None,
                 use_kernel: bool = False, combiner=None,
                 timers: Optional[StageTimers] = None,
                 coalesce: bool = True, max_wait_us: int = 500,
                 linger: str = "fixed", generation: int = 0,
                 profiler=None, oom_sentinel: bool = True,
                 fake_delay_us: int = 0):
        self.worker_id = worker_id
        self.cfg = cfg
        self.batch_size = batch_size
        self.model_idx = model_idx
        self.generation = generation     # reconfig epoch that spawned us (§8)
        self.profiler = profiler         # optional LiveBench sink
        self.device_idx: Optional[int] = None   # set by InferenceSystem
        self.input_queue = input_queue
        self.prediction_queue = prediction_queue
        self.segment_size = segment_size
        self.fake = fake
        # simulated per-compiled-batch device time for fake workers: lets
        # scheduler benchmarks/tests model heterogeneous service rates
        # deterministically (the sleep releases the GIL, so cross-worker
        # parallelism is real even on a small host)
        self.fake_delay_us = fake_delay_us
        self.device = device
        self.combiner = combiner
        self.timers = timers or StageTimers()
        self.coalesce = coalesce
        self.linger_s = max(0, max_wait_us) * 1e-6
        if linger not in ("fixed", "adaptive"):
            raise ValueError(f"linger must be 'fixed' or 'adaptive', "
                             f"got {linger!r}")
        self.linger_mode = linger
        self._depth_gauge = f"queue_depth.{worker_id}"
        self.num_classes = cfg.vocab_size
        self._batch_q: "queue.Queue" = queue.Queue(maxsize=4)
        self._send_q: "queue.Queue" = queue.Queue(maxsize=8)
        self._threads: List[threading.Thread] = []
        self._jax_device = device.jax_devices[0] if device.jax_devices else None

        # preallocated input ring: each slot spans ceil(segment/batch)
        # compiled batches, so one queue hand-off moves a whole segment's
        # worth of coalesced rows through the pipeline (per-batch hand-offs
        # would multiply queue traffic by chunks-per-segment).  The free-list
        # bounds in-flight slots (backpressure).  Mismatched-seq requests
        # draw from a pooled per-width side list instead.
        chunks_per_seg = max(1, -(-segment_size // batch_size))
        self._span = chunks_per_seg * batch_size
        self._ring = [np.zeros((self._span, max_seq), np.int32)
                      for _ in range(RING_SLOTS)]
        self._free_slots: "queue.Queue[int]" = queue.Queue()
        for i in range(len(self._ring)):
            self._free_slots.put(i)
        self._alt_pool: Dict[int, List[np.ndarray]] = {}
        self._alt_lock = threading.Lock()

        try:
            if self._jax_device is not None:
                params = jax.device_put(params, self._jax_device)
            self.params = params
            self.frontend = None
            if cfg.frontend_tokens:
                fe = frontend if frontend is not None else np.zeros(
                    (batch_size, cfg.frontend_tokens, cfg.fdim), np.float32)
                self.frontend = jnp.asarray(fe)
            donate = jax.default_backend() in ("gpu", "tpu")
            self.predict_fn = make_predict_fn(cfg, use_kernel, donate=donate)
            if not fake:   # warm-up compile so READY means actually servable
                warm = jnp.zeros((batch_size, max_seq), jnp.int32)
                np.asarray(self.predict_fn(self.params, warm, self.frontend))
            self.prediction_queue.put(Message(seg.READY, model_idx, None))
        except (MemoryError, RuntimeError, ValueError):
            # paper §II.C.2: {-1, None, None} triggers system shutdown.  A
            # controller-initiated speculative spawn passes oom_sentinel=False
            # so a failed probe rejects ONE reconfig action instead of
            # failing every in-flight request (DESIGN.md §8).
            if oom_sentinel:
                self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    # ---- threads -------------------------------------------------------------
    def start(self):
        for fn, name in [(self._batcher, "batcher"), (self._predictor, "predictor"),
                         (self._sender, "sender")]:
            t = threading.Thread(target=self._guarded, args=(fn,),
                                 name=f"{self.worker_id}-{name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _guarded(self, fn):
        """A stage thread dying mid-request would hang its request (and leak
        its in-flight window slot) forever — convert runtime failures into
        the paper's {-1, None, None} sentinel, which fails every in-flight
        request and shuts the system down."""
        try:
            fn()
        except BaseException:
            self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    def join(self, timeout: float = 30.0):
        for t in self._threads:
            t.join(timeout)

    # ---- batch slots ---------------------------------------------------------
    def _effective_linger(self) -> float:
        """Linger budget for a freshly-opened slot.  ``adaptive`` scales the
        configured ``max_wait_us`` down linearly with the input backlog: a
        deep queue means more rows are already on the way (no need to wait
        for them — they arrive this drain) while an idle queue earns the
        full linger to give concurrent requests a chance to coalesce."""
        if self.linger_mode == "adaptive":
            depth = self.input_queue.qsize()
            return self.linger_s * max(0.0, 1.0 - depth / ADAPTIVE_DEPTH)
        return self.linger_s

    def _open_batch(self, width: int) -> _OpenBatch:
        if width == self._ring[0].shape[1]:
            slot = self._free_slots.get()
            buf = self._ring[slot]
        else:                  # rare: request seq != compiled ring seq
            slot = None
            with self._alt_lock:
                pool = self._alt_pool.setdefault(width, [])
                buf = pool.pop() if pool else None
            if buf is None:
                buf = np.zeros((self._span, width), np.int32)
        return _OpenBatch(slot, buf, width,
                          time.perf_counter() + self._effective_linger())

    def _recycle(self, slot: Optional[int], buf: np.ndarray) -> None:
        if slot is not None:
            self._free_slots.put(slot)
            return
        with self._alt_lock:
            pool = self._alt_pool.setdefault(buf.shape[1], [])
            if len(pool) < ALT_POOL_CAP:
                pool.append(buf)

    # ---- stage 1: batcher ----------------------------------------------------
    def _flush(self, batch: _OpenBatch) -> None:
        """Close a slot: cut it into compiled-batch chunks (full batches plus
        a pow2-bucketed remainder), zero stale pad rows, and hand the whole
        slot to the predictor in ONE queue hop.  Padding counters make
        coalescing efficiency observable."""
        chunks = []                           # (offset, bucket, valid) views
        for off in range(0, batch.fill, self.batch_size):
            valid = min(self.batch_size, batch.fill - off)
            bucket = bucket_for(valid, self.batch_size)
            if valid < bucket:
                batch.buf[off + valid:off + bucket] = 0   # stale tail rows
            chunks.append((off, bucket, valid))
            self.timers.inc("rows_valid", valid)
            self.timers.inc("rows_dispatched", bucket)
        self.timers.inc("batches", len(chunks))
        self.timers.inc("spans", len(batch.spans))
        self._batch_q.put((batch.slot, batch.buf, chunks, batch.spans))

    def _batcher(self):
        open_batch: Optional[_OpenBatch] = None
        while True:
            t0 = time.perf_counter()
            if open_batch is None:
                item = self.input_queue.get()
            else:
                # linger: wait for more rows, bounded by the slot deadline
                wait = open_batch.deadline - time.perf_counter()
                try:
                    if wait > 0:
                        item = self.input_queue.get(timeout=wait)
                    else:
                        item = self.input_queue.get_nowait()
                except queue.Empty:
                    t0 = self.timers.timed("batcher_wait", t0)
                    self._flush(open_batch)   # linger expired
                    open_batch = None
                    self.timers.timed("batch_fill", t0)
                    continue
            t0 = self.timers.timed("batcher_wait", t0)
            self.timers.gauge(self._depth_gauge, self.input_queue.qsize())
            if item == SHUTDOWN:
                if open_batch is not None:
                    self._flush(open_batch)
                # a quiesce(wait=True) racing a drain may have enqueued its
                # FlushBarrier behind this SHUTDOWN — release those waiters
                # instead of leaving them to time out (descriptors cannot
                # land here: routing was removed before the SHUTDOWN)
                while True:
                    try:
                        tail = self.input_queue.get_nowait()
                    except queue.Empty:
                        break
                    if isinstance(tail, FlushBarrier):
                        tail.done.set()
                self._batch_q.put(None)
                return
            if item == FLUSH or isinstance(item, FlushBarrier):
                if open_batch is not None:    # quiesce: close the open slot
                    self._flush(open_batch)
                    open_batch = None
                if isinstance(item, FlushBarrier):
                    item.done.set()           # quiesce(wait=True) barrier
                continue
            req, s = item                     # type: Request, int
            if req.dropped():
                # expired/cancelled: never pack rows — fail fast instead of
                # occupying ring slots (idempotent across workers/segments)
                self.prediction_queue.put(Message(
                    seg.DROPPED, None, None, rid=req.rid))
                self.timers.timed("batch_fill", t0)
                continue
            lo, hi = req.bounds(s)
            width = req.x.shape[1]
            pos = lo
            while pos < hi:
                if open_batch is not None and open_batch.width != width:
                    self._flush(open_batch)   # can't mix seq widths
                    open_batch = None
                if open_batch is None:
                    open_batch = self._open_batch(width)
                f = open_batch.fill
                fill = min(self._span - f, hi - pos)
                open_batch.buf[f:f + fill] = req.x[pos:pos + fill]  # one copy
                # spans never cross a compiled-batch boundary inside the
                # slot, so every span maps to exactly one predictor chunk
                while fill > 0:
                    k = min(self.batch_size - f % self.batch_size, fill)
                    open_batch.spans.append(Span(req, s, pos - lo, f, k))
                    f += k
                    pos += k
                    fill -= k
                open_batch.fill = f
                if f == self._span:
                    self._flush(open_batch)   # full slot: flush immediately
                    open_batch = None
            if open_batch is not None and req.deadline is not None:
                # deadline-aware linger (ROADMAP item f): the slot may wait
                # at most half the tightest packed row's remaining deadline
                # budget — a tight-deadline row never waits out a full
                # linger, and the other half of the budget is left for
                # predict + combine.  Same perf_counter clock as the linger.
                open_batch.deadline = min(
                    open_batch.deadline,
                    (time.perf_counter() + req.deadline) / 2.0)
            if open_batch is not None and req.priority == seg.PRIORITY_HIGH:
                # high-priority rows preempt the linger: flush as soon as
                # the queue runs dry instead of waiting out max_wait_us
                # (anything already queued still coalesces first)
                open_batch.deadline = 0.0
            if not self.coalesce and open_batch is not None:
                self._flush(open_batch)       # PR-1 semantics: per-item flush
                open_batch = None
            self.timers.timed("batch_fill", t0)

    # ---- stage 2: predictor --------------------------------------------------
    def _predictor(self):
        while True:
            item = self._batch_q.get()
            if item is None:
                self._send_q.put(None)
                return
            slot, buf, chunks, spans = item
            t0 = time.perf_counter()
            outs = None
            if self.fake and self.fake_delay_us:
                time.sleep(self.fake_delay_us * 1e-6 * len(chunks))
            if not self.fake:
                outs = []
                for off, bucket, valid in chunks:
                    view = buf[off:off + bucket]
                    if self._jax_device is not None:
                        x = jax.device_put(view, self._jax_device)
                    else:
                        x = jnp.asarray(view)
                    fe = (self.frontend[:bucket]
                          if self.frontend is not None else None)
                    y = self.predict_fn(self.params, x, fe)
                    outs.append(y)             # async dispatch: no block here
            self._send_q.put((slot, buf, spans, outs, chunks, t0))
            self.timers.timed("predict", t0)

    # ---- stage 3: sender -----------------------------------------------------
    def _sender(self):
        """Walk each batch's scatter descriptor and route rows back to their
        segments.  A segment's spans all pass through THIS sender in
        seg_off order (the broadcaster assigns every (segment, model) pair to
        one instance and batches flow FIFO), so the sender reassembles them
        in a local staging dict and forwards ONE segment-level contribution —
        per-span forwarding would multiply combiner/accumulator traffic by
        batches-per-segment and serialize senders on the combiner lock."""
        on_device = self.combiner is not None
        staging: Dict[tuple, list] = {}        # (rid, s) -> [rows, parts]
        while True:
            item = self._send_q.get()
            if item is None:
                return
            slot, buf, spans, outs, chunks, t_dispatch = item
            t0 = time.perf_counter()
            if outs is not None:
                if on_device:
                    for y in outs:
                        y.block_until_ready()  # compute done; stays on device
                else:
                    outs = [np.asarray(y) for y in outs]   # d->h sync
            self._recycle(slot, buf)           # ring slot safe to reuse now
            now = self.timers.timed("transfer", t0)
            if self.profiler is not None and (outs is not None
                                              or self.fake_delay_us):
                # live bench feed (DESIGN.md §8): dispatch-to-materialized
                # wall time for this slot, attributed to its chunks
                # proportionally by dispatched rows
                dt = now - t_dispatch
                total = sum(c[1] for c in chunks) or 1
                for _, bucket, valid in chunks:
                    self.profiler.observe(self.model_idx, self.device.key(),
                                          bucket, valid, dt * bucket / total)
            for sp in spans:
                lo, hi = sp.req.bounds(sp.s)
                key = (sp.req.rid, sp.s)
                st = staging.get(key)
                if st is None:
                    st = staging[key] = [0, []]
                # FIFO pipeline order is what makes append-reassembly valid;
                # seg_off pins that assumption instead of trusting it
                assert sp.seg_off == st[0], (key, sp.seg_off, st[0])
                if outs is not None:
                    # chunk-aligned spans: batch_off names the chunk directly
                    y = outs[sp.batch_off // self.batch_size]
                    off = sp.batch_off % self.batch_size
                    st[1].append(y[off:off + sp.n])
                st[0] += sp.n
                if st[0] < hi - lo:
                    continue                   # segment still in flight
                del staging[key]
                if outs is None:               # fake predictor: instant zeros
                    P = np.zeros((hi - lo, self.num_classes), np.float32)
                elif len(st[1]) == 1:
                    P = st[1][0]
                elif on_device:
                    P = jnp.concatenate(st[1], axis=0)
                else:
                    P = np.concatenate(st[1], axis=0)
                if on_device:
                    self.combiner.add(sp.req, sp.s, self.model_idx, P)
                else:
                    self.prediction_queue.put(Message(
                        sp.s, self.model_idx, np.asarray(P),
                        rid=sp.req.rid))
