"""Coalescing-scheduler tests (ISSUE 2): cross-request batch packing,
ensemble selection (``predict(members=...)``) under coalesced batches,
device_combine parity, deterministic flush counts, row-count (not
message-count) accounting in the combiner and accumulator, the quiesce
flush, mismatched-seq buffer pooling, and best-fit input-buffer reuse."""
import queue
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.accumulator import PredictionAccumulator
from repro.serving.combiner import DeviceCombiner
from repro.serving.segments import Message, Request
from repro.serving.system import InferenceSystem
from repro.serving.worker import ALT_POOL_CAP

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def oracle(cfgs, params, X, weights=None):
    w = weights if weights is not None else [1 / len(cfgs)] * len(cfgs)
    out = np.zeros((X.shape[0], cfgs[0].vocab_size), np.float32)
    for i, (c, p) in enumerate(zip(cfgs, params)):
        fe = jnp.zeros((X.shape[0], c.frontend_tokens, c.fdim)) \
            if c.frontend_tokens else None
        lg, _ = M.forward(p, c, jnp.asarray(X), fe)
        out += np.asarray(lg[:, -1, :c.vocab_size]) * w[i]
    return out


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


def small_batch(rng, k, sizes=(3, 5, 6, 9, 12)):
    return [rng.integers(0, 512, (sizes[i % len(sizes)], SEQ)).astype(np.int32)
            for i in range(k)]


# ---- ensemble selection under coalesced batches ------------------------------

def test_members_subsets_interleaved_under_coalescing(ens2):
    """predict(members=...) stays correct when rows from requests with
    DIFFERENT member subsets coalesce into shared batches; subset weights
    renormalize per request."""
    cfgs, params = ens2
    w = np.array([0.75, 0.25], np.float32)
    Xs = small_batch(np.random.default_rng(10), 12)
    member_sets = [[0], [1], [0, 1]]
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                     combine="weighted", weights=w, coalesce=True,
                     max_in_flight=12) as s:
        handles = [s.predict_async(x, members=member_sets[i % 3])
                   for i, x in enumerate(Xs)]
        Ys = [h.result(120.0) for h in handles]
    for i, (x, y) in enumerate(zip(Xs, Ys)):
        ms = member_sets[i % 3]
        sub_w = w[ms] / w[ms].sum()
        ref = oracle([cfgs[m] for m in ms], [params[m] for m in ms], x, sub_w)
        np.testing.assert_allclose(y, ref, atol=2e-5)


@pytest.mark.parametrize("combine", ["mean", "vote", "pallas"])
def test_device_combine_parity_under_coalescing(ens2, combine):
    """Acceptance: device_combine=True and =False produce identical outputs
    under coalescing, for interleaved small requests with member subsets."""
    cfgs, params = ens2
    Xs = small_batch(np.random.default_rng(11), 10)
    member_sets = [[0, 1], [1], [0]]
    outs = {}
    for dc in (True, False):
        with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                         combine=combine, coalesce=True, max_in_flight=10,
                         device_combine=dc) as s:
            handles = [s.predict_async(x, members=member_sets[i % 3])
                       for i, x in enumerate(Xs)]
            outs[dc] = [h.result(120.0) for h in handles]
    for y_dev, y_host in zip(outs[True], outs[False]):
        np.testing.assert_allclose(y_dev, y_host, atol=1e-5)


def test_deterministic_flush_counts_under_coalescing(ens2):
    """Whatever way spans pack into batches, each (request, segment) posts
    exactly one device partial per device: message counts stay
    devices x segments."""
    cfgs, params = ens2
    Xs = small_batch(np.random.default_rng(12), 9, sizes=(5, 20, 40))
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     coalesce=True, max_in_flight=9) as s:
        before = s.accumulator.data_messages
        posted0 = sum(c.partials_posted for c in s.combiners.values())
        handles = [s.predict_async(x) for x in Xs]
        for h in handles:
            h.result(120.0)
        n_segments = sum(-(-x.shape[0] // 16) for x in Xs)
        assert s.accumulator.data_messages - before == n_segments
        posted = sum(c.partials_posted for c in s.combiners.values()) - posted0
        assert posted == n_segments


def test_single_segment_requests_spread_across_instances(ens2):
    """Striping rotates by request id, so a stream of single-segment (small)
    requests spreads across a model's data-parallel instances instead of
    pinning every request to the s=0 instance."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [8, 0]])                  # model 0 data-parallel on d0+d1
    with make_system(cfgs, params, A, segment_size=16, fake=True,
                     coalesce=True, max_in_flight=8) as s:
        handles = [s.predict_async(np.zeros((5, SEQ), np.int32))
                   for _ in range(8)]
        for h in handles:
            h.result(60.0)
        # d1 hosts only model 0's second instance: it must have seen work
        assert s.combiners[1].partials_posted > 0
        assert s.combiners[0].partials_posted > 0


# ---- row-count accounting (combiner / accumulator units) ---------------------

def _mk_request(n, num_classes=8, segment_size=16, members=(0, 1),
                weights=(0.6, 0.4)):
    return Request(0, np.zeros((n, SEQ), np.int32), n, num_classes,
                   segment_size, list(members),
                   {m: w for m, w in zip(members, weights)}, "weighted")


@pytest.mark.parametrize("to_device", [False, True])
def test_combiner_counts_rows_not_messages(to_device):
    """A member's segment arriving split across row-ranges still flushes
    exactly once, when members x segment_rows rows have been folded."""
    req = _mk_request(12)
    rng = np.random.default_rng(0)
    P0 = rng.normal(size=(12, 8)).astype(np.float32)
    P1 = rng.normal(size=(12, 8)).astype(np.float32)
    conv = (lambda a: jnp.asarray(a)) if to_device else (lambda a: a)
    q = queue.Queue()
    comb = DeviceCombiner("d0", q)
    comb.begin(req, {0: 2})
    comb.add(req, 0, 0, conv(P0[:5]), row_lo=0)       # member 0, split rows
    assert q.empty() and comb.partials_posted == 0
    comb.add(req, 0, 1, conv(P1), row_lo=0)           # member 1, whole seg
    assert q.empty()                                  # rows: 5 + 12 of 24
    comb.add(req, 0, 0, conv(P0[5:]), row_lo=5)       # member 0, tail rows
    msg = q.get_nowait()
    assert comb.partials_posted == 1 and msg.count == 2 and msg.m is None
    np.testing.assert_allclose(msg.P, 0.6 * P0 + 0.4 * P1, atol=1e-5)
    assert not comb._parts and not comb._expected     # state fully retired


def test_combiner_pallas_rule_row_spans():
    """The accumulate-into-partial Pallas kernel fold stays correct when a
    member's contribution arrives as row spans of the segment."""
    req = _mk_request(12, num_classes=16)
    req.combine = "pallas"
    rng = np.random.default_rng(1)
    P0 = rng.normal(size=(12, 16)).astype(np.float32)
    P1 = rng.normal(size=(12, 16)).astype(np.float32)
    q = queue.Queue()
    comb = DeviceCombiner("d0", q)
    comb.begin(req, {0: 2})
    comb.add(req, 0, 0, jnp.asarray(P0[:7]), row_lo=0)
    comb.add(req, 0, 0, jnp.asarray(P0[7:]), row_lo=7)
    comb.add(req, 0, 1, jnp.asarray(P1), row_lo=0)
    msg = q.get_nowait()
    np.testing.assert_allclose(msg.P, 0.6 * P0 + 0.4 * P1, atol=1e-5)


def test_accumulator_counts_rows_not_messages():
    """A request owes n x members member-rows; split row_lo messages debit
    their row counts and completion fires exactly when rows close."""
    req = _mk_request(10, weights=(0.5, 0.5))
    rng = np.random.default_rng(2)
    P0 = rng.normal(size=(10, 8)).astype(np.float32)
    P1 = rng.normal(size=(10, 8)).astype(np.float32)
    q = queue.Queue()
    acc = PredictionAccumulator(q, 2, combine="weighted",
                                weights=np.array([0.5, 0.5], np.float32))
    acc.start()
    try:
        handle = acc.begin(req)
        assert handle.remaining == 20                  # rows, not messages
        q.put(Message(0, 0, P0[:6], rid=0, row_lo=0))
        q.put(Message(0, 0, P0[6:], rid=0, row_lo=6))
        q.put(Message(0, 1, P1, rid=0, row_lo=0))
        Y = handle.result(30.0)
        np.testing.assert_allclose(Y, 0.5 * P0 + 0.5 * P1, atol=1e-5)
        assert handle.messages == 3
    finally:
        acc.stop()


def test_accumulator_device_partial_debits_count_times_rows():
    req = _mk_request(10, weights=(0.5, 0.5))
    q = queue.Queue()
    acc = PredictionAccumulator(q, 2)
    acc.start()
    try:
        handle = acc.begin(req)
        partial = np.full((10, 8), 2.0, np.float32)
        q.put(Message(0, None, partial, rid=0, count=2))
        Y = handle.result(30.0)
        np.testing.assert_allclose(Y, partial)
    finally:
        acc.stop()


# ---- linger / quiesce --------------------------------------------------------

def test_quiesce_flushes_lingering_partial_batch(ens2):
    """With an effectively-infinite linger a lone small request sits in an
    open batch; quiesce() force-flushes it."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=30_000_000) as s:
        h = s.predict_async(np.zeros((3, SEQ), np.int32))
        time.sleep(0.3)
        assert not h.done.is_set()          # batch is lingering open
        s.quiesce()
        assert np.all(h.result(30.0) == 0)


def test_bounded_linger_flushes_without_quiesce(ens2):
    """The default linger bounds single-request latency: a partial batch
    flushes on its own once max_wait_us expires."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=1000) as s:
        t0 = time.perf_counter()
        s.predict(np.zeros((3, SEQ), np.int32), timeout=30.0)
        assert time.perf_counter() - t0 < 5.0


# ---- buffer pooling ----------------------------------------------------------

def test_mismatched_seq_buffers_are_pooled(ens2):
    """Requests whose seq width differs from the compiled ring draw batcher
    buffers from a bounded per-width pool instead of allocating per slot."""
    cfgs, params = ens2
    alt_seq = SEQ // 2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True) as s:
        for _ in range(6):
            Y = s.predict(np.zeros((20, alt_seq), np.int32), timeout=30.0)
            assert Y.shape == (20, cfgs[0].vocab_size)
        for w in s.workers:
            pools = w._alt_pool
            assert alt_seq in pools and len(pools[alt_seq]) >= 1
            assert all(len(p) <= ALT_POOL_CAP for p in pools.values())
            assert all(b.shape == (w._span, alt_seq)
                       for b in pools[alt_seq])


def test_take_buffer_best_fit(ens2):
    """_take_buffer picks the SMALLEST fitting pooled buffer, so one huge
    early request can't pin oversized buffers for every later request."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True) as s:
        big = np.zeros((512, SEQ), np.int32)
        mid = np.zeros((64, SEQ), np.int32)
        small = np.zeros((32, SEQ), np.int32)
        with s._pool_lock:
            s._buffer_pool[:] = [big, mid, small]
        got = s._take_buffer(40, SEQ)
        assert got is mid                   # best fit, not first fit (big)
        with s._pool_lock:
            assert any(b is big for b in s._buffer_pool)
            assert any(b is small for b in s._buffer_pool)


# ---- metrics -----------------------------------------------------------------

def test_padding_counters_and_queue_gauge(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(13).integers(0, 512, (20, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True) as s:
        s.predict(X, timeout=30.0)
        c = s.serving_counters()
        assert c["batches"] > 0 and c["spans"] > 0
        assert 0 < c["rows_valid"] <= c["rows_dispatched"]
        assert 0 < c["padding_efficiency"] <= 1.0
        g = s.serving_gauges()
        depth_keys = [k for k in g if k.startswith("queue_depth.")]
        assert depth_keys and all(g[k]["max"] >= 0 for k in depth_keys)
