"""Cross-worker work stealing (DESIGN.md §8, ROADMAP items c/g).

The replanner corrects *minutes*-scale drift; this module corrects
*milliseconds*-scale imbalance: when one member-instance's admission queue
runs deep while a data-parallel sibling idles, queued segment descriptors are
re-routed to the sibling.  Three invariants make a steal safe:

* **atomic ownership** — ``AdmissionQueue.steal`` pops descriptors under the
  queue lock, so a descriptor is processed by exactly one batcher; a whole
  ``(request, segment)`` moves at once, so the sender's span reassembly is
  untouched (all of a segment's spans still flow through one batcher).
  Selection is deadline-aware (ROADMAP item i): the tightest remaining
  deadline budget moves first — it gains the most from the idle sibling;
* **expected-row maps move with the work** — with the device-resident
  partial combine, the source device's combiner expected one contribution
  for the stolen (request, segment); ``unexpect``/``expect_one`` transfer
  that expectation (flushing the source partial early when the remaining
  members' rows already closed it), so row-count flush accounting still
  closes on both devices;
* **topology consistency** — steals run under the system's submit lock, so
  they cannot interleave with a spawn/drain (a descriptor is never re-routed
  into a queue behind a ``SHUTDOWN``) or a racing broadcaster registering a
  new request's expected maps.
"""
from __future__ import annotations

from typing import List

from repro.serving.segments import PRIORITY_NORMAL
from repro.serving.worker import Worker


def _transfer(req, s: int, src: Worker, dst: Worker) -> None:
    """Move the combiner expectation for (req, s) from src's device to
    dst's.  Same-device siblings share a combiner (no move); a dropped
    (cancelled/expired) request's maps were already torn down — the
    descriptor still forwards so the destination batcher posts the DROPPED
    resolution."""
    if (src.combiner is None or dst.combiner is None
            or src.combiner is dst.combiner or req.dropped()):
        return
    if src.combiner.unexpect(req, s):
        dst.combiner.expect_one(req, s)


def migrate_descriptors(system, src: Worker, siblings: List[Worker]) -> int:
    """Drain-side migration: move EVERYTHING still queued on ``src`` —
    including high-priority descriptors, which :meth:`AdmissionQueue.steal`
    deliberately never touches — to its siblings round-robin.  Caller
    (``InferenceSystem.drain_instance``) holds the submit lock and has
    already removed ``src`` from routing."""
    targets = [w for w in siblings if w is not src]
    if not targets:
        return 0
    stolen = src.input_queue.drain_descriptors()
    for i, (req, s) in enumerate(stolen):
        dst = targets[i % len(targets)]
        _transfer(req, s, src, dst)
        dst.input_queue.put((req, s), req.priority)
    return len(stolen)


def steal_from(system, src: Worker, dst: Worker, max_items: int = 32) -> int:
    """Re-route up to ``max_items`` queued descriptors from ``src`` to its
    data-parallel sibling ``dst``.  Returns the number moved (0 when either
    worker is no longer a routable instance — e.g. a concurrent drain)."""
    if src.model_idx != dst.model_idx or src is dst:
        raise ValueError("work stealing requires data-parallel siblings")
    with system._submit_lock:
        inst = system._instances.get(src.model_idx, [])
        if src not in inst or dst not in inst:
            return 0
        stolen = src.input_queue.steal(max_items)
        for req, s in stolen:
            _transfer(req, s, src, dst)
            dst.input_queue.put((req, s), req.priority)
    return len(stolen)


def balance_member(system, m: int, *, threshold: int = 4,
                   max_items: int = 32, profile=None) -> int:
    """One balancing pass for member ``m``: steal from the instance with the
    longest estimated *drain time* to the one with the shortest.

    Raw queue depth is the wrong imbalance signal under heterogeneous
    batch sizes: a batch-128 sibling with 28 queued segments drains sooner
    than a batch-8 sibling with 20.  With a live profile (``LiveBench``),
    each instance's backlog is weighted by its measured per-segment service
    time and the move count is chosen to equalize drain times; without one,
    siblings are assumed equal-rate and this reduces to halving the depth
    gap.  ``threshold`` is in descriptors, measured at the *destination*'s
    service rate (how many descriptors of gap make the steal worthwhile).
    Backlog is the normal-priority admission depth **plus the chunk
    dispatch-queue backlog in segment units** (chunk-granular pipeline:
    flushed-but-undispatched work is real drain time the admission depth
    can no longer see, but it is not stealable — only the admission part
    moves).  High-priority descriptors are never stolen, so counting them
    (``qsize``) would make the fast loop chase phantom imbalance it can
    move nothing for.  Returns descriptors moved.

    The fast loop runs every couple of milliseconds, so an idle system must
    not pay for it: a lock-free peek at the per-queue depths (list copy is
    atomic under the GIL; each queue has its own lock) skips the member
    without ever touching the global submit lock the request hot path
    contends on — only a member with actual stealable backlog proceeds to
    the locked snapshot."""
    peek = list(system._instances.get(m, ()))
    if len(peek) < 2 or all(
            w.input_queue.depth(PRIORITY_NORMAL) == 0 for w in peek):
        return 0
    inst = system.instances(m)
    if len(inst) < 2:
        return 0
    rates = []
    for w in inst:
        t_seg = None
        if profile is not None:
            t_seg = profile.segment_time(m, w.device.key(), w.batch_size,
                                         system.segment_size)
        # admission backlog + flushed-but-undispatched chunks (in segment
        # units) — the dispatch queue is drain time too, just not stealable
        depth = w.input_queue.depth(PRIORITY_NORMAL) + \
            w.dispatch_backlog() / max(1, w.chunks_per_segment)
        rates.append((w, depth, t_seg))
    if any(t is None for _, _, t in rates):
        t_by_w = {id(w): 1.0 for w, _, _ in rates}     # cold profile: equal
    else:
        t_by_w = {id(w): t for w, _, t in rates}
    drains = [(n * t_by_w[id(w)], w, n) for w, n, _ in rates]
    deep_drain, deep_w, _ = max(drains, key=lambda t: t[0])
    idle_drain, idle_w, _ = min(drains, key=lambda t: t[0])
    t_deep, t_idle = t_by_w[id(deep_w)], t_by_w[id(idle_w)]
    # descriptors the idle sibling could absorb inside the drain-time gap
    gap = (deep_drain - idle_drain) / t_idle
    if deep_w is idle_w or gap < threshold:
        return 0
    # move enough to equalize drain times, not just halve the depth gap
    k = int((deep_drain - idle_drain) / (t_deep + t_idle))
    if k < 1:
        return 0
    return steal_from(system, deep_w, idle_w, min(max_items, k))
