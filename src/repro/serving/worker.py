"""A worker: one model instance pinned to one device at one batch size.

Faithful to paper Fig. 2 — three asynchronous threads per worker:
  * the *batcher* turns incoming segment ids into padded batches,
  * the *predictor* owns the params on its device and runs the jitted step,
  * the *prediction sender* reassembles batch outputs into segment
    predictions and posts the {s, m, P} message.

Hardware adaptation (DESIGN.md §2): the paper uses one OS process per worker
(TF1 sessions hold the GIL); with JAX, XLA executions release the GIL and
dispatch is asynchronous, so threads + per-worker queues give the same
overlap without IPC serialization overhead.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.devices import DeviceSpec
from repro.serving import segments as seg
from repro.serving.segments import Message, SHUTDOWN


def make_predict_fn(cfg: ModelConfig, use_kernel: bool = False) -> Callable:
    """Classification-style serving fn: tokens (b,S) -> last-token class
    scores (b, C) with C = the unpadded vocab (the paper's f(x)->y)."""
    from repro.models import forward

    def predict(params, tokens, frontend):
        logits, _ = forward(params, cfg, tokens, frontend, use_kernel=use_kernel)
        return logits[:, -1, :cfg.vocab_size]

    return jax.jit(predict)


class Worker:
    def __init__(self, worker_id: str, cfg: ModelConfig, params,
                 device: DeviceSpec, batch_size: int,
                 input_queue: "queue.Queue[int]",
                 prediction_queue: "queue.Queue[Message]",
                 model_idx: int, shared_x: np.ndarray, segment_size: int,
                 *, fake: bool = False, frontend: Optional[np.ndarray] = None,
                 use_kernel: bool = False):
        self.worker_id = worker_id
        self.cfg = cfg
        self.batch_size = batch_size
        self.model_idx = model_idx
        self.input_queue = input_queue
        self.prediction_queue = prediction_queue
        self.shared_x = shared_x
        self.segment_size = segment_size
        self.fake = fake
        self.device = device
        self.num_classes = cfg.vocab_size
        self._batch_q: "queue.Queue" = queue.Queue(maxsize=4)
        self._send_q: "queue.Queue" = queue.Queue(maxsize=8)
        self._threads = []
        self._jax_device = device.jax_devices[0] if device.jax_devices else None

        try:
            if self._jax_device is not None:
                params = jax.device_put(params, self._jax_device)
            self.params = params
            self.frontend = None
            if cfg.frontend_tokens:
                fe = frontend if frontend is not None else np.zeros(
                    (batch_size, cfg.frontend_tokens, cfg.fdim), np.float32)
                self.frontend = jnp.asarray(fe)
            self.predict_fn = make_predict_fn(cfg, use_kernel)
            if not fake:   # warm-up compile so READY means actually servable
                warm = jnp.zeros((batch_size, shared_x.shape[1]), jnp.int32)
                np.asarray(self.predict_fn(self.params, warm, self.frontend))
            self.prediction_queue.put(Message(seg.READY, model_idx, None))
        except (MemoryError, RuntimeError, ValueError):
            # paper §II.C.2: {-1, None, None} triggers system shutdown
            self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    # ---- threads -------------------------------------------------------------
    def start(self):
        for fn, name in [(self._batcher, "batcher"), (self._predictor, "predictor"),
                         (self._sender, "sender")]:
            t = threading.Thread(target=fn, name=f"{self.worker_id}-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def join(self, timeout: float = 30.0):
        for t in self._threads:
            t.join(timeout)

    def _batcher(self):
        while True:
            item = self.input_queue.get()
            if item == SHUTDOWN:
                self._batch_q.put(None)
                return
            s, nb_samples = item              # (segment id, request size)
            lo = seg.start(s, self.segment_size)
            hi = seg.end(s, self.segment_size, nb_samples)
            data = self.shared_x[lo:hi]
            batches = []
            for i in range(0, len(data), self.batch_size):
                chunk = data[i:i + self.batch_size]
                n = len(chunk)
                if n < self.batch_size:        # pad to the compiled shape
                    chunk = np.concatenate(
                        [chunk, np.zeros((self.batch_size - n,) + chunk.shape[1:],
                                         chunk.dtype)])
                batches.append((chunk, n))
            self._batch_q.put((s, hi - lo, batches))

    def _predictor(self):
        while True:
            item = self._batch_q.get()
            if item is None:
                self._send_q.put(None)
                return
            s, total, batches = item
            outs = []
            for chunk, n in batches:
                if self.fake:
                    outs.append((np.zeros((self.batch_size, self.num_classes),
                                          np.float32), n))
                    continue
                x = jnp.asarray(chunk)
                if self._jax_device is not None:
                    x = jax.device_put(x, self._jax_device)
                y = self.predict_fn(self.params, x, self.frontend)
                outs.append((y, n))            # async dispatch: no block here
            self._send_q.put((s, total, outs))

    def _sender(self):
        while True:
            item = self._send_q.get()
            if item is None:
                return
            s, total, outs = item
            parts = [np.asarray(y)[:n] for y, n in outs]   # sync point
            P = np.concatenate(parts, axis=0)
            assert P.shape[0] == total
            self.prediction_queue.put(Message(s, self.model_idx, P))
