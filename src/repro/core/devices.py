"""Device abstraction for the allocation problem.

The paper's "device" is one GPU or CPU socket.  Our generalization (DESIGN.md
§2): a device is an **allocation cell** — one chip, or a sub-mesh slice with
model-parallel sharding inside.  ``jax_devices`` carries the backing runtime
devices; on this CPU container every cell maps to the single CpuDevice while
keeping distinct *logical* memory budgets, which is exactly what the
allocation algorithms reason about.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax

GiB = 1024 ** 3

# TPU v5e chip constants (the deployment target; see ROOFLINE in the brief)
TPU_V5E_PEAK_FLOPS = 197e12          # bf16
TPU_V5E_HBM_BW = 819e9               # bytes/s
TPU_V5E_HBM_BYTES = 16 * GiB
TPU_V5E_LINK_BW = 50e9               # bytes/s per ICI link

# Reference V100 / host constants for paper-shaped simulated clusters
V100_PEAK_FLOPS = 125e12 / 8         # fp32 tensor-core derate for inference mix
V100_HBM_BW = 900e9
V100_HBM_BYTES = 32 * GiB
HOST_PEAK_FLOPS = 1.5e12
HOST_BW = 80e9


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    kind: str                        # "GPU" | "CPU" | "TPU"
    memory_bytes: int
    peak_flops: float
    mem_bw: float
    jax_devices: Tuple = ()          # backing jax.Device cell (may be empty = simulated)

    @property
    def is_accelerator(self) -> bool:
        return self.kind in ("GPU", "TPU")

    def key(self) -> str:
        return f"{self.kind}:{self.name}:{self.memory_bytes}"


def simulated_gpus(n: int, memory_bytes: int = V100_HBM_BYTES) -> list:
    return [DeviceSpec(f"gpu{i}", "GPU", memory_bytes, V100_PEAK_FLOPS, V100_HBM_BW)
            for i in range(n)]


def simulated_tpus(n: int, memory_bytes: int = TPU_V5E_HBM_BYTES) -> list:
    return [DeviceSpec(f"tpu{i}", "TPU", memory_bytes, TPU_V5E_PEAK_FLOPS,
                       TPU_V5E_HBM_BW) for i in range(n)]


def host_cpus(n: int = 1, memory_bytes: int = 16 * GiB) -> list:
    """CPU devices; backed by the real CpuDevice when present."""
    backing = tuple(d for d in jax.devices() if d.platform == "cpu")[:1]
    return [DeviceSpec(f"cpu{i}", "CPU", memory_bytes, HOST_PEAK_FLOPS, HOST_BW,
                       jax_devices=backing) for i in range(n)]


def tpu_cells(mesh_devices: Sequence, cell_size: int, *,
              memory_bytes: int = TPU_V5E_HBM_BYTES) -> list:
    """Partition a flat device list into model-parallel cells of ``cell_size``
    chips each — the beyond-paper 'cells' extension (DESIGN.md §9.2)."""
    cells = []
    flat = list(mesh_devices)
    for i in range(0, len(flat) - cell_size + 1, cell_size):
        group = tuple(flat[i:i + cell_size])
        cells.append(DeviceSpec(
            f"cell{i // cell_size}", "TPU",
            memory_bytes * cell_size,
            TPU_V5E_PEAK_FLOPS * cell_size,
            TPU_V5E_HBM_BW * cell_size,
            jax_devices=group))
    return cells
