"""Request traces: record live arrivals, replay them anywhere.

A *trace* is a list of :class:`TraceEvent` — one per offered request, sorted
by arrival offset ``t`` (seconds from trace start).  The schema is the
minimum the planner cares about: when the request arrived, how many rows it
carried, its priority class, its relative deadline, and the member subset it
asked for.  Payload contents are deliberately not recorded — the scheduler
is shape-driven, so a trace replays bit-equivalently with synthetic rows.

Producers:
  * :class:`TraceRecorder` attached to ``InferenceSystem.trace_recorder``
    (or via ``launch/serve.py --record-trace``) records live offered load.
  * ``repro.serving.sim.traces`` generates synthetic Poisson / MMPP /
    diurnal traces.

Consumers:
  * ``repro.serving.sim`` replays traces under a virtual clock.
  * ``benchmarks/serving_hotpath.py --replay-trace`` replays them against a
    real (fake-device) ``InferenceSystem`` with wall-clock pacing.

On disk a trace is JSONL, one event per line:

    {"t": 0.0123, "rows": 64, "priority": "high", "deadline_ms": 50.0,
     "members": [0, 2]}

``deadline_ms`` and ``members`` are ``null`` when unset (no deadline / full
ensemble).
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from typing import IO, Iterable, List, Optional, Sequence

from repro.serving.segments import PRIORITY_HIGH, priority_level

__all__ = ["TraceEvent", "TraceRecorder", "save_trace", "load_trace"]


@dataclass(frozen=True)
class TraceEvent:
    """One offered request: arrival offset + admission-relevant shape."""
    t: float                               # seconds from trace start
    rows: int
    priority: str = "normal"               # "high" | "normal"
    deadline_ms: Optional[float] = None    # relative deadline, None = none
    members: Optional[Sequence[int]] = None  # None = full ensemble

    def level(self) -> int:
        return priority_level(self.priority)

    def to_json(self) -> str:
        return json.dumps({
            "t": round(float(self.t), 9), "rows": int(self.rows),
            "priority": self.priority,
            "deadline_ms": self.deadline_ms,
            "members": list(self.members) if self.members is not None else None,
        }, separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "TraceEvent":
        d = json.loads(line)
        members = d.get("members")
        return cls(t=float(d["t"]), rows=int(d["rows"]),
                   priority=str(d.get("priority", "normal")),
                   deadline_ms=(None if d.get("deadline_ms") is None
                                else float(d["deadline_ms"])),
                   members=None if members is None else tuple(members))


class TraceRecorder:
    """Thread-safe arrival recorder.

    ``record()`` is called from the broadcaster under submission load, so it
    does no I/O by default — events accumulate in memory and are written by
    ``save()`` / ``close()``.  Pass ``stream`` (or ``path``) to additionally
    append each event as it arrives (crash-safe recording for long serves).

    The clock is ``time.perf_counter`` rebased to the first recorded event,
    so traces always start near t=0.
    """

    def __init__(self, path: Optional[str] = None,
                 stream: Optional[IO[str]] = None,
                 clock=time.perf_counter):
        self._lock = threading.Lock()
        self._events: List[TraceEvent] = []
        self._clock = clock
        self._t0: Optional[float] = None
        self._own_stream = False
        if path is not None and stream is None:
            stream = open(path, "w", encoding="utf-8")
            self._own_stream = True
        self._stream = stream

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def record(self, rows: int, *, priority=PRIORITY_HIGH + 1,
               deadline_ms: Optional[float] = None,
               members: Optional[Sequence[int]] = None,
               t: Optional[float] = None) -> TraceEvent:
        """Record one offered request.  ``priority`` accepts the public
        string form ("high"/"normal") or the internal int level."""
        cls = "high" if priority_level(priority) == PRIORITY_HIGH else "normal"
        with self._lock:
            if t is None:
                now = self._clock()
                if self._t0 is None:
                    self._t0 = now
                t = now - self._t0
            ev = TraceEvent(t=t, rows=int(rows), priority=cls,
                            deadline_ms=deadline_ms,
                            members=tuple(members) if members is not None
                            else None)
            self._events.append(ev)
            if self._stream is not None:
                self._stream.write(ev.to_json() + "\n")
            return ev

    def events(self) -> List[TraceEvent]:
        """Snapshot, sorted by arrival time (stable for equal t)."""
        with self._lock:
            return sorted(self._events, key=lambda e: e.t)

    def save(self, path: str) -> int:
        evs = self.events()
        save_trace(path, evs)
        return len(evs)

    def close(self) -> None:
        with self._lock:
            if self._stream is not None:
                if self._own_stream:
                    self._stream.close()
                else:
                    self._stream.flush()
                self._stream = None


def save_trace(path: str, events: Iterable[TraceEvent]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        for ev in events:
            f.write(ev.to_json() + "\n")


def load_trace(path: str) -> List[TraceEvent]:
    out: List[TraceEvent] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(TraceEvent.from_json(line))
    out.sort(key=lambda e: e.t)
    return out
