"""End-to-end tracing suite (ISSUE 9, DESIGN.md §13): flight-recorder ring
bounds and stride realignment, grouped-record decode and the pack-instant
join that recovers per-chunk request attribution, Perfetto JSON export
round-trips, anomaly-triggered dumps, connected admission→combine
timelines on a live fake-device system, control-plane annotation instants
(steal / quarantine replay / demotion / cancellation), sim-vs-live span
comparability on the virtual clock, and the Prometheus metrics surface
(text exposition, log-bucket latency histograms, the gauge-insert race).
"""
import json
import threading
import types

import numpy as np
import jax
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.metrics import (LATENCY_BOUNDS_S, StageTimers,
                                   prometheus_text)
from repro.serving.segments import RequestCancelled
from repro.serving.system import InferenceSystem
from repro.serving.tracing import FlightRecorder, Tracer, _decode, pack_times

SEQ = 16


def _X(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 64, (n, SEQ)).astype(np.int32)


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    A = np.array(A)
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    kw.setdefault("max_seq", SEQ)
    kw.setdefault("fake", True)
    kw.setdefault("tracing", True)
    return InferenceSystem(cfgs, params, alloc, **kw)


def _names(trace):
    return {ev["name"] for ev in trace["traceEvents"] if ev["ph"] != "M"}


# ---- flight recorder --------------------------------------------------------

def test_ring_bounds_drop_oldest():
    r = FlightRecorder(capacity=8)
    for i in range(20):
        r.append(("X", f"ev{i}", float(i), 0.5, i, None, None, None))
    assert len(r) == 8
    events = r.snapshot()
    assert [e[1] for e in events] == [f"ev{i}" for i in range(12, 20)]
    r.clear()
    assert len(r) == 0 and r.snapshot() == []


def test_snapshot_realigns_misaligned_copy():
    # a copy that starts mid-event (torn by a concurrent wrap) must be
    # re-chunked from the ph column, not decoded off-by-k
    r = FlightRecorder(capacity=8)
    r._ring.extend((1.0, 2.0, 3.0))        # stray half-event prefix
    for i in range(3):
        r.append(("X", f"ev{i}", float(i), 0.1, i, None, None, None))
    events = r.snapshot()
    assert [e[1] for e in events] == ["ev0", "ev1", "ev2"]
    assert all(e[0] == "X" for e in events)


# ---- flat-event decode ------------------------------------------------------

def test_decode_grouped_dispatch_round():
    ts = (1.0, 2.0, 3.0)
    ph, name, t0, dur, rid, args = _decode(
        "G", "dispatch_wait", 1.0, 5.0, None, pack_times(ts), 0.25, 3)
    assert (ph, name, rid) == ("G", "dispatch_wait", None)
    assert args == {"t_enq": ts, "predict_dur": 0.25, "chunks": 3}
    # uncommitted round: no predict attached
    _, _, _, _, _, args = _decode(
        "G", "dispatch_wait", 1.0, 5.0, None, pack_times(ts), None, None)
    assert args == {"t_enq": ts}


def test_decode_grouped_single_span():
    # correlation-key form (slot a = the round's pop time) ...
    _, _, _, _, _, args = _decode("g", "transfer", 6.0, 0.5, None, 5.0, 2,
                                  None)
    assert args == {"t_pop": 5.0, "chunks": 2}
    # ... and the inline packed-times form
    _, _, _, _, _, args = _decode("g", "transfer", 6.0, 0.5, None,
                                  pack_times((1.5,)), 1, None)
    assert args == {"t_enq": (1.5,), "chunks": 1}


def test_decode_slot_keys_and_passthrough():
    assert _decode("X", "combine", 0.0, 0.1, 7, 2, 1, True)[5] == \
        {"s": 2, "m": 1, "posted": True}
    assert _decode("X", "accumulate", 0.0, 0.1, 7, 3, 64, None)[5] == \
        {"s": 3, "rows": 64}
    assert _decode("i", "pack", 0.0, 0.0, 1, 16, 0, None)[5] == \
        {"chunks": 16, "level": 0}
    assert _decode("i", "complete", 0.0, 0.0, 1, None, None, None)[5] is None
    assert _decode("i", "demote", 0.0, 0.0, 1, {"drop": [1]}, None,
                   None)[5] == {"drop": [1]}


# ---- the pack-instant join --------------------------------------------------

def _joined_tracer():
    """Hand-built worker tracks exercising the export-time join: two
    flushes (rid 1, then rids 2+3 coalesced), one grouped dispatch round
    covering both, one grouped transfer keyed by the round's pop time."""
    tr = Tracer(enabled=True, capacity=64)
    tr.ring("w0/batcher").append(("i", "pack", 10.0, 0.0, 1, 1, 0, None))
    tr.ring("w0/batcher").append(("i", "pack", 11.0, 0.0, (2, 3), 1, 0, None))
    tr.ring("w0/predict").append(
        ("G", "dispatch_wait", 10.0, 12.0, None, pack_times((10.0, 11.0)),
         0.5, 2))
    tr.ring("w0/sender").append(("g", "transfer", 13.0, 0.2, None, 12.0, 2,
                                 None))
    tr.ring("accumulator").append(("i", "complete", 14.0, 0.0, 1, None,
                                   None, None))
    return tr


def test_timeline_resolves_grouped_records_per_rid():
    tr = _joined_tracer()
    tl1 = tr.timeline(1)
    names1 = [(tid, name) for tid, _ph, name, _t0, _dur in tl1]
    assert ("w0/predict", "dispatch_wait") in names1
    assert ("w0/predict", "predict") in names1
    assert ("w0/sender", "transfer") in names1
    assert ("accumulator", "complete") in names1
    # rid 1's chunk waited 10.0 -> 12.0; rid 2 only sees the 11.0 chunk
    dw1 = [(t0, dur) for _tid, _ph, n, t0, dur in tl1 if n == "dispatch_wait"]
    assert dw1 == [(10.0, 2.0)]
    dw2 = [(t0, dur) for _tid, _ph, n, t0, dur in tr.timeline(2)
           if n == "dispatch_wait"]
    assert dw2 == [(11.0, 1.0)]
    assert not any(n == "complete" for _t, _p, n, _a, _b in tr.timeline(2))
    # sorted by start, rooted at the earliest event
    assert [e[3] for e in tl1] == sorted(e[3] for e in tl1)


def test_export_attributes_grouped_records():
    trace = _joined_tracer().export()
    by_name = {}
    for ev in trace["traceEvents"]:
        if ev["ph"] != "M":
            by_name.setdefault(ev["name"], []).append(ev)
    dws = sorted(by_name["dispatch_wait"], key=lambda e: e["ts"])
    assert len(dws) == 2 and all(e["ph"] == "X" for e in dws)
    assert dws[0]["args"] == {"rid": 1}
    assert dws[1]["args"] == {"rids": [2, 3]}
    # the attached predict span and the two-hop transfer join see the
    # union of the round's requests
    assert by_name["predict"][0]["args"] == {"rids": [1, 2, 3], "chunks": 2}
    assert by_name["transfer"][0]["args"] == {"rids": [1, 2, 3], "chunks": 2}
    # ts/dur rebased to the earliest event, in microseconds
    assert dws[0]["ts"] == 0.0 and dws[0]["dur"] == pytest.approx(2e6)


def test_wrapped_pack_instant_resolves_to_no_rid():
    # bounded-recorder semantics: a chunk whose pack instant fell off the
    # ring keeps its span but loses request attribution
    tr = Tracer(enabled=True, capacity=64)
    tr.ring("w0/predict").append(
        ("G", "dispatch_wait", 10.0, 12.0, None, pack_times((10.0,)),
         None, None))
    ev = [e for e in tr.export()["traceEvents"] if e["ph"] == "X"]
    assert len(ev) == 1 and ev[0]["args"] == {}
    assert tr.timeline(1) == []


# ---- export schema / anomaly dumps ------------------------------------------

def test_export_json_roundtrip_and_schema():
    tr = _joined_tracer()
    trace = json.loads(json.dumps(tr.export()))
    assert set(trace) == {"traceEvents", "displayTimeUnit", "metadata"}
    phs = {ev["ph"] for ev in trace["traceEvents"]}
    assert phs <= {"M", "X", "i"}          # grouped records never leak
    tids = {ev["tid"] for ev in trace["traceEvents"]}
    track_names = {ev["args"]["name"] for ev in trace["traceEvents"]
                   if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert track_names == {"w0/batcher", "w0/predict", "w0/sender",
                           "accumulator"}
    assert len(tids) == len(track_names) + 1   # + the process row
    assert trace["metadata"]["clock"] == "perf_counter"


def test_virtual_clock_tagged_in_metadata():
    tr = Tracer(enabled=True, clock=lambda: 5.0)
    assert tr.export()["metadata"]["clock"] == "virtual"


def test_anomaly_dumps_tagged_and_bounded():
    t = [0.0]
    tr = Tracer(enabled=True, capacity=64, clock=lambda: t[0], max_dumps=2,
                burst_n=3, burst_window_s=1.0)
    tr.span("w0/predict", "predict", 0.0, 0.5, rid=1)
    for t[0] in (0.0, 0.1, 0.2):           # 3 misses inside the window
        tr.note_deadline_miss()
    assert [d["metadata"]["dump_trigger"]["trigger"] for d in tr.dumps()] \
        == ["deadline_miss_burst"]
    t[0] = 0.3                             # rate-limited within the window
    tr.note_deadline_miss()
    assert len(tr.dumps()) == 1
    # the dump snapshots the spans leading up to the anomaly
    assert "predict" in _names(tr.dumps()[0])
    for t[0] in (2.0, 2.05, 2.1):          # fresh burst after the window
        tr.note_deadline_miss()
    assert len(tr.dumps()) == 2
    tr.anomaly("watchdog_stall", "w0")     # bounded: oldest dump evicted
    dumps = tr.dumps()
    assert len(dumps) == 2
    assert dumps[-1]["metadata"]["dump_trigger"]["trigger"] == \
        "watchdog_stall"
    assert [a["trigger"] for a in tr.anomalies()] == \
        ["deadline_miss_burst", "deadline_miss_burst", "watchdog_stall"]


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    tr.span("w0/predict", "predict", 0.0, 0.5, rid=1)
    tr.instant("admission", "demote", rid=1)
    tr.note_deadline_miss()
    assert tr.anomaly("watchdog_stall") is None
    assert tr.tracks() == {} and tr.dumps() == []


# ---- live system: connected timelines + control-plane annotations -----------

def test_live_timelines_connected_and_exportable(ens2, tmp_path):
    from repro.serving.client import EnsembleClient
    cfgs, params = ens2
    s = make_system(cfgs, params, [[8, 0], [0, 8]])
    try:
        handles = [s.predict_async(_X(24, seed=i)) for i in range(3)]
        for h in handles:
            h.result(60.0)
        tr = s.tracer
        for h in handles:
            tl = tr.timeline(h.req.rid)
            names = {name for _tid, _ph, name, _t0, _dur in tl}
            # the connected admission -> combine view of one request
            assert {"submit", "pack", "dispatch_wait", "predict",
                    "transfer", "complete"} <= names
            assert "accumulate" in names or "combine" in names
            assert tl[0][2] == "submit"    # rooted at admission
            assert [e[3] for e in tl] == sorted(e[3] for e in tl)
        trace = json.loads(json.dumps(
            EnsembleClient(system=s).dump_trace(
                str(tmp_path / "trace.json"))))
        assert {ev["ph"] for ev in trace["traceEvents"]} <= {"M", "X", "i"}
        with open(tmp_path / "trace.json") as f:
            assert json.load(f) == trace
        # every completed request is attributed somewhere in the export
        for h in handles:
            rid = h.req.rid
            assert any(a.get("rid") == rid or rid in a.get("rids", ())
                       for a in (ev.get("args", {})
                                 for ev in trace["traceEvents"]))
    finally:
        s.shutdown()


def test_steal_and_quarantine_replay_instants(ens2):
    cfgs, params = ens2
    # two data-parallel instances of one member: quarantining one re-stripes
    # onto its sibling and annotates the admission track
    s = make_system(cfgs[:1], params[:1], [[8], [8]])
    try:
        hook = s._trace_queue_event("w9")
        req = types.SimpleNamespace(rid=5)
        hook("steal", [(req, 0), (req, 1)], 1)
        hook("enqueue", [(req, 2)], 0)     # covered by the submit span
        w = s.workers[0]
        s.quarantine_instance(w)
        h = s.predict_async(_X(16))        # sibling still serves
        h.result(60.0)
        events = s.tracer.tracks()["admission"]
        steal = [e for e in events if e[1] == "queue_steal"]
        assert len(steal) == 1
        assert steal[0][4] == (5,) and steal[0][5]["units"] == 2
        assert not any(e[1] == "queue_enqueue" for e in events)
        assert any(e[1] == "quarantine"
                   and e[5] == {"worker": w.worker_id} for e in events)
        assert any(e[1] == "quarantine_replay"
                   and e[5]["worker"] == w.worker_id for e in events)
    finally:
        s.shutdown()


def test_demote_and_cancel_instants(ens2):
    cfgs, params = ens2
    # slow fake devices keep requests in flight long enough to act on them
    s = make_system(cfgs, params, [[8, 0], [0, 8]], fake_delay_us=20000)
    try:
        h1 = s.predict_async(_X(64))
        assert s.demote_request(h1.req.rid, [0])
        h1.result(120.0)
        h2 = s.predict_async(_X(64))
        assert h2.cancel()
        with pytest.raises(RequestCancelled):
            h2.result(30.0)
        events = s.tracer.tracks()["admission"]
        demote = [e for e in events
                  if e[1] == "demote" and e[4] == h1.req.rid]
        assert demote and demote[0][5] == {"drop": [1], "kept": [0]}
        acc = s.tracer.tracks()["accumulator"]
        assert any(e[1] == "fail" and e[4] == h2.req.rid
                   and e[5] == {"error": "RequestCancelled"} for e in acc)
        assert any(e[1] == "complete" and e[4] == h1.req.rid for e in acc)
    finally:
        s.shutdown()


# ---- sim-vs-live comparability ----------------------------------------------

def test_sim_trace_spans_comparable_to_live():
    from repro.serving.sim import (ServiceModel, SimSystem, WorkerSpec,
                                   poisson_trace)
    svc = ServiceModel.from_delays({0: 300, 1: 300})
    sim = SimSystem(svc, [WorkerSpec(0, 16), WorkerSpec(1, 16)],
                    segment_size=16, tracing=True)
    sim.run(poisson_trace(30, rate=200.0, seed=0))
    trace = sim.tracer.export()
    assert trace["metadata"]["clock"] == "virtual"
    # the sim emits the same stage names as the live pipeline, so a live
    # run and its replay produce directly comparable timelines
    assert {"submit", "pack", "dispatch_wait", "predict",
            "complete"} <= _names(trace)
    rid0 = trace["metadata"]["base_s"]     # rebased: first event at ts 0
    xs = [ev for ev in trace["traceEvents"] if ev["ph"] == "X"]
    assert min(ev["ts"] for ev in xs) == 0.0 and rid0 >= 0.0
    tl = sim.tracer.timeline(0)
    assert {name for _t, _p, name, _a, _b in tl} >= \
        {"submit", "dispatch_wait", "predict", "complete"}


# ---- metrics: Prometheus exposition + histograms ----------------------------

def test_prometheus_text_families():
    t = StageTimers()
    t.inc("batches", 3)
    t.add("predict", 0.5)
    t.gauge("queue_depth.w0", 4)
    t.gauge("health.w0", 0)
    t.gauge("hp_p50_ms", 2.5)
    text = prometheus_text(t, extra_gauges={"in_flight": 2})
    assert "# TYPE serving_batches_total counter" in text
    assert "serving_batches_total 3" in text
    assert 'serving_stage_seconds_total{stage="predict"} 0.5' in text
    assert 'serving_stage_operations_total{stage="predict"} 1' in text
    assert 'serving_queue_depth{worker="w0"} 4' in text
    assert 'serving_worker_health{worker="w0"} 0' in text
    assert "serving_hp_p50_ms 2.5" in text
    assert "serving_in_flight 2" in text
    assert text.endswith("\n")


def test_prometheus_latency_histogram_cumulative():
    t = StageTimers()
    for _ in range(10):
        t.latency("normal", 0.001)
    for _ in range(10):
        t.latency("normal", 0.1)
    t.latency("normal", 1e9)               # overflow bucket
    text = prometheus_text(t)
    buckets = [ln for ln in text.splitlines()
               if ln.startswith('serving_request_latency_seconds_bucket'
                                '{class="normal"')]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)        # cumulative
    assert buckets[-1].startswith(
        'serving_request_latency_seconds_bucket{class="normal",le="+Inf"}')
    assert counts[-1] == 21
    assert 'serving_request_latency_seconds_count{class="normal"} 21' in text
    assert len(buckets) == len(LATENCY_BOUNDS_S) + 1


def test_latency_snapshot_histogram_accuracy():
    t = StageTimers()
    for _ in range(99):
        t.latency("high", 0.010)
    t.latency("high", 1.0)
    snap = t.latency_snapshot()
    assert set(snap) == {"high"}
    assert set(snap["high"]) == {"n", "p50_ms", "p99_ms"}
    assert snap["high"]["n"] == 100
    # log buckets at sqrt(2) resolution: estimates land within one bucket
    assert 10 / 2 ** 0.5 <= snap["high"]["p50_ms"] <= 10 * 2 ** 0.5
    assert 1000 / 2 ** 0.5 <= snap["high"]["p99_ms"] <= 1000 * 2 ** 0.5
    # the hp_p50 gauge tracks the histogram median
    assert t.gauge_snapshot()["hp_p50_ms"]["last"] == \
        pytest.approx(snap["high"]["p50_ms"])


def test_gauge_snapshot_races_first_time_inserts():
    # regression: snapshot iterating the gauge dict while workers insert
    # new queue_depth.<id> keys must not blow up mid-resize
    t = StageTimers()
    stop = threading.Event()
    errors = []

    def writer(k):
        i = 0
        while not stop.is_set():
            t.gauge(f"queue_depth.w{k}_{i}", float(i))
            i += 1

    def reader():
        try:
            while not stop.is_set():
                for name, g in t.gauge_snapshot().items():
                    assert g["last"] >= 0.0
        except Exception as e:             # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(3)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    th = threading.Timer(0.5, stop.set)
    th.start()
    stop.wait(5.0)
    for th_ in threads:
        th_.join(5.0)
    assert not errors
