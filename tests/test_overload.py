"""Overload robustness suite (DESIGN.md §11): brownout levels, cost-aware
admission, backpressure and the confidence-gated cascade.

Fast tests (tier-1) run against fake-device systems or pure logic:
hysteresis cannot flap, infeasible deadlines 429 fast with a computed
Retry-After, the byte budget bounds admission, and degraded results cannot
poison the full-quality cache key space.

``chaos``-marked tests use real (tiny) models so output *values* matter:
mid-flight demotion must match a directly-requested member subset, the
cascade must reconstruct the full-ensemble combine, level 0 must be
bit-identical to an uncontrolled system, and brownout must compose with
worker quarantine/replay without losing a single request.
"""
import time

import numpy as np
import pytest

from repro.serving.admission import AdmissionBudget
from repro.serving.client import _retry_after_of, quality_salt
from repro.serving.control.overload import (BrownoutController,
                                            build_tier_table,
                                            estimate_drain_s)
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.request_cache import PredictionCache
from repro.serving.segments import Overloaded, PredictOptions
from repro.serving.server import _header_s

SEQ = 16


def _X(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 64, (n, SEQ)).astype(np.int32)


@pytest.fixture(scope="module")
def ens2():
    import jax
    from repro import models as M
    from repro.configs import ensemble
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    from repro.core.allocation import AllocationMatrix
    from repro.core.devices import host_cpus
    from repro.serving.system import InferenceSystem
    A = np.array(A)
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    kw.setdefault("max_seq", SEQ)
    return InferenceSystem(cfgs, params, alloc, **kw)


# ---- pure logic -------------------------------------------------------------

def test_tier_table_drops_worst_cost_per_weight():
    # member 1 is expensive per unit weight, member 2 cheap: 1 goes first,
    # the cheapest-per-weight member (2) survives to the last tier
    tiers = build_tier_table(np.array([0.5, 0.3, 0.2], np.float32),
                             [1.0, 2.0, 0.1])
    assert tiers[0] == (0, 1, 2)
    assert tiers[1] == (0, 2)
    assert tiers[-1] == (2,)


def test_retry_after_header_grammar():
    assert _header_s(0.05) == "1"        # integer seconds, never below 1
    assert _header_s(1.0) == "1"
    assert _header_s(1.2) == "2"


def test_client_retry_after_parsing():
    class E:
        headers = {"Retry-After": "3"}
    assert _retry_after_of(E(), '{"retry_after_s": 0.25}') == 0.25
    assert _retry_after_of(E(), "not json") == 3.0          # header fallback

    class E2:
        headers = {}
    assert _retry_after_of(E2(), "not json") is None


# ---- hysteresis -------------------------------------------------------------

def test_hysteresis_does_not_flap(ens2):
    cfgs, params = ens2
    s = make_system(cfgs, params, [[8, 8]], fake=True)
    try:
        ctl = BrownoutController(s, tiers=[(0, 1), (0,)],
                                 high=1.0, low=0.4, up_ticks=2, down_ticks=3,
                                 demote_inflight=False, feasibility=False)
        # oscillating around the high threshold: the consecutive-tick
        # counter resets every dip, so the level must hold at 0
        for _ in range(10):
            ctl.step(1.05)
            ctl.step(0.95)
        assert ctl.level == 0 and ctl.transitions == 0
        # sustained overload: exactly up_ticks ticks raise the level
        ctl.step(1.5)
        assert ctl.level == 0
        ctl.step(1.5)
        assert ctl.level == 1
        # oscillating around the low threshold: still no flap downward
        for _ in range(10):
            ctl.step(0.45)
            ctl.step(0.35)
        assert ctl.level == 1
        # inside the dead band: hold
        for _ in range(10):
            ctl.step(0.7)
        assert ctl.level == 1
        # sustained recovery: down_ticks consecutive quiet ticks step down
        for _ in range(3):
            ctl.step(0.1)
        assert ctl.level == 0
        assert ctl.transitions == 2
        assert s.serving_counters().get("brownout_transitions") == 2
    finally:
        s.shutdown()


def test_plan_members_level0_and_tiering(ens2):
    cfgs, params = ens2
    s = make_system(cfgs, params, [[8, 8]], fake=True)
    try:
        ctl = BrownoutController(s, tiers=[(0, 1), (0,)],
                                 demote_inflight=False, feasibility=False)
        opts = PredictOptions()
        # level 0: the exact input object comes back, quality 1.0
        members = [0, 1]
        kept, q = ctl.plan_members(members, opts)
        assert kept is members and q == 1.0
        ctl.step(2.0)
        ctl.step(2.0)
        assert ctl.level == 1
        kept, q = ctl.plan_members([0, 1], opts)
        assert kept == [0] and 0.0 < q < 1.0
        # high priority is never tier-planned
        kept, q = ctl.plan_members([0, 1], PredictOptions(priority="high"))
        assert kept == [0, 1] and q == 1.0
    finally:
        s.shutdown()


# ---- cost-aware admission + backpressure ------------------------------------

def test_infeasible_deadline_fails_fast_with_retry_after(ens2):
    cfgs, params = ens2
    # 5ms of simulated device time per chunk: a 1ms deadline is infeasible
    # even at zero backlog, so the rejection is deterministic
    s = make_system(cfgs, params, [[8, 8]], fake=True, fake_delay_us=5000)
    try:
        BrownoutController(s, tiers=[(0, 1), (0,)], demote_inflight=False)
        t0 = time.perf_counter()
        with pytest.raises(Overloaded) as ei:
            s.predict(_X(64), options=PredictOptions(deadline_ms=1.0))
        elapsed = time.perf_counter() - t0
        assert elapsed < 0.5                    # fail-fast, not a 504 later
        ra = ei.value.retry_after_s
        assert ra is not None and 0.0 < ra < 60.0
        assert s.serving_counters().get("admission_rejections") == 1
        # deadline-less and generously-deadlined requests still pass
        assert s.predict(_X(16), timeout=60.0).shape[0] == 16
        y = s.predict(_X(16), timeout=60.0,
                      options=PredictOptions(deadline_ms=30_000.0))
        assert y.shape[0] == 16
    finally:
        s.shutdown()


def test_byte_budget_backpressure(ens2):
    cfgs, params = ens2
    budget = AdmissionBudget(max_bytes=5000)    # one 64x16 int32 request
    s = make_system(cfgs, params, [[8, 8]], fake=True, fake_delay_us=20000,
                    admission_budget=budget)
    try:
        h1 = s.predict_async(_X(64))            # charges 64*16*4 = 4096 B
        assert budget.bytes_used == 4096
        with pytest.raises(Overloaded) as ei:
            s.predict_async(_X(64, seed=1))
        assert ei.value.retry_after_s is not None
        assert budget.rejected == 1
        assert h1.result(60.0).shape[0] == 64
        # completion credits the charge back (ownership transferred to the
        # request at submit); then admission opens again
        deadline = time.monotonic() + 5.0
        while budget.bytes_used and time.monotonic() < deadline:
            time.sleep(0.01)
        assert budget.bytes_used == 0
        assert s.predict(_X(8), timeout=60.0).shape[0] == 8
    finally:
        s.shutdown()


def test_budget_admits_oversized_request_when_idle():
    b = AdmissionBudget(max_bytes=100)
    assert b.try_charge(4096, 64)               # idle: never wedge a client
    assert not b.try_charge(1, 1)
    b.credit(4096, 64)
    assert b.bytes_used == 0


def test_drain_estimate_floor(ens2):
    cfgs, params = ens2
    s = make_system(cfgs, params, [[8, 8]], fake=True)
    try:
        assert estimate_drain_s(s) >= 0.05      # client backoff floor
        assert estimate_drain_s(s, floor_s=0.0) == 0.0   # idle, unfloored
        assert s.retry_after_s() >= 0.05
    finally:
        s.shutdown()


# ---- cache quality poisoning ------------------------------------------------

def test_degraded_results_cannot_poison_cache():
    assert quality_salt(b"s", 1.0) == b"s"      # full quality: unchanged key
    assert quality_salt(b"s", 0.5) != b"s"
    assert quality_salt(b"s", 0.5) != quality_salt(b"s", 0.25)
    cache = PredictionCache(16)
    X = _X(4)
    cache.insert(X, np.ones((4, 8), np.float32), quality_salt(b"s", 0.5))
    hits, misses = cache.lookup(X, b"s")
    assert len(misses) == 4                     # degraded entry never served
    hits, misses = cache.lookup(X, quality_salt(b"s", 0.5))
    assert not misses                           # same-tier lookups do hit


def test_predict_through_skips_insert_for_degraded_results():
    class _H:
        def __init__(self, q):
            self.quality = q

        def result(self, timeout=None):
            return np.zeros((4, 8), np.float32)

    class _Sys:
        def __init__(self, q):
            self.q = q

        def predict_async(self, X):
            return _H(self.q)

    cache = PredictionCache(16)
    cache.predict_through(_Sys(0.5), _X(4))
    assert len(cache._store) == 0               # degraded: not cached
    cache.predict_through(_Sys(1.0), _X(4))
    assert len(cache._store) == 4


# ---- real-model value consistency (chaos band) ------------------------------

@pytest.mark.chaos
def test_midflight_demotion_matches_direct_subset(ens2):
    """Demoting member 1 mid-flight must produce the same values as asking
    for members=[0] up front: forgiveness + renormalization, not zeros."""
    cfgs, params = ens2
    # sustained 'slow' fault holds member 1's predictor long enough for
    # the demotion to land before any of its chunks are forwarded
    fp = FaultPlan(FaultSpec(stage="predictor", kind="slow", stall_s=0.05,
                             repeat=True, worker="w1"))
    s = make_system(cfgs, params, [[8, 8]], fault_plan=fp)
    try:
        X = _X(64)
        Yref = s.predict(X, members=[0], timeout=60.0)
        h = s.predict_async(X)
        assert s.demote_request(h.req.rid, {0})
        Y = h.result(60.0)
        assert np.allclose(Y, Yref, atol=1e-5)
        assert h.quality < 1.0
        c = s.serving_counters()
        assert c.get("requests_demoted") == 1
        assert c.get("rows_demoted", 0) >= 64
    finally:
        s.shutdown()


@pytest.mark.chaos
def test_level0_bit_identical_and_cascade_restores_full_quality(ens2):
    cfgs, params = ens2
    s = make_system(cfgs, params, [[8, 8]])
    try:
        X = _X(48)
        Yref = s.predict(X, timeout=60.0)       # no controller attached
        ctl = BrownoutController(s, tiers=[(0, 1), (0,)],
                                 cascade_margin=float("inf"),
                                 demote_inflight=False, feasibility=False)
        # level 0 is a strict no-op: bit-identical, quality untouched
        h0 = s.predict_async(X)
        assert np.array_equal(h0.result(60.0), Yref)
        assert h0.quality == 1.0
        ctl.step(2.0)
        ctl.step(2.0)
        assert ctl.level == 1
        # margin threshold of +inf forces escalation: the cheap tier plus
        # the escalated members must reconstruct the full-ensemble combine
        h = s.predict_async(X)
        Y = h.result(60.0)
        assert np.allclose(Y, Yref, atol=1e-5)
        assert h.quality == 1.0
        assert s.serving_counters().get("cascade_escalations") == 1
    finally:
        s.shutdown()


@pytest.mark.chaos
def test_brownout_composes_with_supervision_zero_lost(ens2):
    """A worker crash (quarantine + replay) during an active brownout must
    still lose zero requests: every handle resolves with a quality-stamped
    result, never a hang or an untyped failure."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="sender", kind="raise", after=2,
                             worker="w0.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=2000, fault_plan=fp,
                    supervise=True, supervise_interval_s=0.02)
    try:
        ctl = BrownoutController(s, tiers=[(0, 1), (0,)],
                                 demote_inflight=True, feasibility=False)
        hs = [s.predict_async(_X(48, seed=i)) for i in range(10)]
        ctl.step(2.0)
        ctl.step(2.0)                           # level 1: demote in flight
        assert ctl.level == 1
        for h in hs:
            y = h.result(60.0)
            assert y.shape == (48, cfgs[0].vocab_size)
            assert 0.0 < h.quality <= 1.0
        c = s.serving_counters()
        assert c.get("quarantines") == 1
        assert c.get("requests_demoted", 0) >= 1
        # and the system still serves after both events
        assert s.predict(_X(16), timeout=60.0).shape[0] == 16
    finally:
        s.shutdown()
