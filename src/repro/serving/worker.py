"""A worker: one model instance pinned to one device at one batch size.

Faithful to paper Fig. 2 — three asynchronous threads per worker:
  * the *batcher* coalesces incoming segment rows into padded batches,
  * the *predictor* owns the params on its device and runs the jitted step,
  * the *prediction sender* scatters batch outputs back to their segments
    and forwards them (device partial or {s, m, P} message).

Hardware adaptation (DESIGN.md §2): the paper uses one OS process per worker
(TF1 sessions hold the GIL); with JAX, XLA executions release the GIL and
dispatch is asynchronous, so threads + per-worker queues give the same
overlap without IPC serialization overhead.

Coalescing scheduler (DESIGN.md §3): the paper's batching process forms
batches strictly within one (request, segment) pair, so heavy traffic of
many small requests runs nothing but padded remainder buckets.  Here the
batcher drains its input queue and packs rows from *multiple* in-flight
requests/segments into full compiled batches:

  * the unit moved through the pipeline is a **ring slot** spanning
    ``ceil(segment/batch)`` compiled batches, plus a **scatter descriptor**
    — a list of :class:`~repro.serving.segments.Span` entries mapping slot
    row-ranges back to (request, segment, segment-row) coordinates.  Spans
    never cross a compiled-batch boundary, so each span belongs to exactly
    one predictor chunk;
  * a full slot flushes immediately; a partial slot lingers at most
    ``max_wait_us`` for more rows (bounded latency), and ``SHUTDOWN`` /
    ``FLUSH`` (quiesce) force an immediate flush;
  * a flushed slot is cut into full compiled batches plus a short remainder
    padded to the next **power-of-two bucket** (not the full compiled batch)
    — one jitted callable serves every bucket, with jit's shape cache
    bounding compilations to ~log2(batch) entries, and input buffers are
    donated on accelerators so XLA can reuse them;
  * ``coalesce=False`` restores the PR-1 one-item-at-a-time batching (each
    (request, segment) flushes its own slot) as a measurement baseline;
  * slots come from a **preallocated ring** (free-list backpressure bounds
    in-flight memory).  Mismatched-seq requests (request width != compiled
    ring width) draw buffers from a small per-width side pool instead of
    allocating per slot.

Chunk-granular dispatch (DESIGN.md §3, ROADMAP items e/k): a flushed slot
is no longer slot-indivisible through the predictor.  The batcher cuts it
into its compiled chunks *at flush time* and each chunk enters a per-worker
priority :class:`~repro.serving.admission.DispatchQueue` as an independent
:class:`~repro.serving.segments.ChunkDesc`:

  * a high-priority chunk (any span from a ``priority="high"`` request)
    jumps every queued bulk chunk — the non-preemptible head shrinks from
    up to ``RING_SLOTS`` flushed slots to the single chunk already
    dispatched plus the dispatch-ahead window;
  * high-priority packing is **express**: it never blocks on the ring free
    list (a pooled side buffer serves when all slots are in flight with
    bulk), and a bulk descriptor's own wait for a free slot is
    *interruptible* — high-priority descriptors landing mid-wait are
    admitted first;
  * the predictor keeps up to ``dispatch_ahead`` (K) async XLA dispatches
    outstanding — the device never starves while the queue reorders, and K
    bounds the committed (non-preemptible) work ahead of a late-arriving
    high-priority chunk;
  * a chunk whose every span belongs to a cancelled/expired request is
    dropped at dequeue time (never dispatched): the predictor posts the
    ``DROPPED`` resolution and the rows land on the ``rows_dropped``
    counter instead of occupying device time;
  * slot recycling moves to a per-slot outstanding-chunk **refcount**
    (:class:`~repro.serving.segments.SlotRef`): the ring buffer recycles
    only after every chunk's output is materialized — on CPU ``device_put``
    may alias host memory, so one chunk retiring early must not free rows
    another chunk still reads;
  * the sender forwards a (request, segment) contribution **as soon as its
    last span's chunk returns** (early per-segment forwarding) rather than
    when the whole slot retires; spans may now materialize out of order
    within a segment (a mixed chunk rides the high-priority class while its
    bulk siblings queue), so reassembly is row-count-based with parts keyed
    by segment offset.  Still ONE contribution per (request, segment) —
    per-span forwarding would multiply combiner/accumulator traffic by
    chunks-per-segment;
  * per-stage wall-clock counters (metrics.StageTimers) instrument the
    batcher wait, batch fill, per-class dispatch-queue wait
    (``dispatch_wait.high`` / ``dispatch_wait.normal``), predict dispatch,
    and device sync/transfer; padding counters (``rows_valid`` /
    ``rows_dispatched``) and the ``queue_depth`` gauge expose coalescing
    efficiency.

Request-API admission (DESIGN.md §7): the input queue is a two-level
:class:`~repro.serving.admission.AdmissionQueue` — high-priority descriptors
drain before normal ones, and packing a high-priority request's rows
*preempts the linger* (the open slot's deadline collapses to "flush as soon
as the queue runs dry") so a latency-sensitive request never waits out
``max_wait_us`` behind its own batch.  A descriptor whose request is past
its deadline or cancelled is dropped instead of packed: the batcher posts
``Message(DROPPED, ...)`` and the accumulator fails the request, so expired
work never occupies ring slots or device time.  With ``linger="adaptive"``
the linger budget scales down with the queue backlog (deep queue → flush
immediately, idle queue → full ``max_wait_us``; ROADMAP item b).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.devices import DeviceSpec
from repro.kernels import quant as kquant
from repro.kernels.ops import pow2_clamp
from repro.serving import segments as seg
from repro.serving.admission import DispatchQueue, chunk_level
from repro.serving.faults import FaultPlan
from repro.serving.metrics import StageTimers
from repro.serving.tracing import pack_times
from repro.serving.segments import (FLUSH, ChunkDesc, FlushBarrier, Message,
                                    Request, SHUTDOWN, SlotRef, Span)

MIN_BUCKET = 8
RING_SLOTS = 4          # in-flight slot bound per worker
ALT_POOL_CAP = 4        # pooled mismatched-seq buffers per width
ADAPTIVE_DEPTH = 8      # linger="adaptive": backlog at which linger hits 0
DISPATCH_AHEAD = 16     # default outstanding async XLA dispatches (K):
                        # throughput-friendly — K bounds the committed
                        # (non-preemptible) window, so latency-sensitive
                        # mixed-traffic deployments set it small (1-2)

# worker health states (exported via serving_gauges / GET /metrics)
HEALTH_READY = 0        # all stage threads alive and making progress
HEALTH_DEGRADED = 1     # a stage has been mid-work past the watchdog
HEALTH_DEAD = 2         # a stage thread died (crashed event / not alive)
# heartbeat states: a stage blocked on an empty queue is WAITing (healthy
# at any age — idleness is not a stall); only an ACTIVE stamp going stale
# means the stage is stuck mid-work
_HB_WAIT = 0
_HB_ACTIVE = 1


def bucket_for(n: int, batch_size: int) -> int:
    """Compiled batch shape for an ``n``-row chunk: the full batch size, or
    the next power of two >= n (min 8) for remainder chunks."""
    if n >= batch_size:
        return batch_size
    return pow2_clamp(n, MIN_BUCKET, batch_size)


def make_predict_fn(cfg: ModelConfig, use_kernel: bool = False,
                    donate: bool = False, member_dtype: str = "fp32",
                    quant_out: bool = False) -> Callable:
    """Classification-style serving fn: tokens (b,S) -> last-token class
    scores (b, C) with C = the unpadded vocab (the paper's f(x)->y).
    ``donate`` hands the token buffer to XLA for reuse (accelerators only —
    CPU ignores donation and would warn on every compile).

    ``member_dtype`` != "fp32" expects params wrapped by
    :func:`repro.kernels.quant.quantize_params` — dequantization runs inside
    the jit so it fuses into the forward pass (weight-only quantization:
    storage/H2D are narrow, math is fp32).  ``quant_out`` additionally
    quantizes the output logits per row (symmetric int8 over classes) and
    returns ``(q (b, C) int8, scale (b, 1) f32)`` for the fused
    dequant-weight-accumulate combine epilogue; per-row scales are uniform
    across classes, so argmax/vote downstream is unaffected."""
    from repro.models import forward

    wrapped = member_dtype != "fp32"

    def predict(params, tokens, frontend):
        p = kquant.dequantize_params(params) if wrapped else params
        logits, _ = forward(p, cfg, tokens, frontend, use_kernel=use_kernel)
        out = logits[:, -1, :cfg.vocab_size]
        if quant_out:
            return kquant.quantize_symmetric(out, axis=-1)
        return out

    return jax.jit(predict, donate_argnums=(1,) if donate else ())


def _span_rids(spans):
    """rid annotation for a chunk-level trace event: the bare rid, or a
    tuple when the chunk coalesced rows from several requests."""
    if len(spans) == 1:
        return spans[0].req.rid
    return tuple({sp.req.rid for sp in spans})


class _OpenBatch:
    """The batcher's in-progress coalesced batch."""
    __slots__ = ("slot", "buf", "width", "fill", "spans", "deadline")

    def __init__(self, slot, buf, width: int, deadline: float):
        self.slot = slot             # ring index, or None (side-pool buffer)
        self.buf = buf
        self.width = width
        self.fill = 0
        self.spans: List[Span] = []
        self.deadline = deadline     # linger expiry (perf_counter seconds)


class Worker:
    def __init__(self, worker_id: str, cfg: ModelConfig, params,
                 device: DeviceSpec, batch_size: int,
                 input_queue: "queue.Queue",
                 prediction_queue: "queue.Queue[Message]",
                 model_idx: int, max_seq: int, segment_size: int,
                 *, fake: bool = False, frontend: Optional[np.ndarray] = None,
                 use_kernel: bool = False, combiner=None,
                 timers: Optional[StageTimers] = None,
                 coalesce: bool = True, max_wait_us: int = 500,
                 linger: str = "fixed", generation: int = 0,
                 profiler=None, oom_sentinel: bool = True,
                 fake_delay_us: int = 0,
                 dispatch_ahead: int = DISPATCH_AHEAD,
                 fault_plan: Optional[FaultPlan] = None,
                 nan_guard: bool = False, tracer=None,
                 member_dtype: str = "fp32",
                 dispatch_queue: Optional[type] = None):
        self.worker_id = worker_id
        self.cfg = cfg
        self.batch_size = batch_size
        self.member_dtype = kquant.validate_member_dtype(member_dtype)
        self.model_idx = model_idx
        self.generation = generation     # reconfig epoch that spawned us (§8)
        self.profiler = profiler         # optional LiveBench sink
        self.device_idx: Optional[int] = None   # set by InferenceSystem
        self.input_queue = input_queue
        self.prediction_queue = prediction_queue
        self.segment_size = segment_size
        self.fake = fake
        # simulated per-compiled-batch device time for fake workers: lets
        # scheduler benchmarks/tests model heterogeneous service rates
        # deterministically (the sleep releases the GIL, so cross-worker
        # parallelism is real even on a small host)
        self.fake_delay_us = fake_delay_us
        self.device = device
        self.combiner = combiner
        self.timers = timers or StageTimers()
        self.coalesce = coalesce
        self.linger_s = max(0, max_wait_us) * 1e-6
        if linger not in ("fixed", "adaptive"):
            raise ValueError(f"linger must be 'fixed' or 'adaptive', "
                             f"got {linger!r}")
        self.linger_mode = linger
        self._depth_gauge = f"queue_depth.{worker_id}"
        self.num_classes = cfg.vocab_size
        # chunk-granular dispatch: priority queue batcher -> predictor, plus
        # the dispatch-ahead window (K outstanding async XLA dispatches —
        # the semaphore is acquired before a chunk is *committed*, so the
        # queue may reorder right up to the moment of dispatch)
        self.dispatch_ahead = max(1, dispatch_ahead)
        # pluggable dispatch policy (ROADMAP item m): FIFO-within-priority
        # by default; ``EDFDispatchQueue`` orders by request deadline
        self._dispatch_q = (dispatch_queue or DispatchQueue)()
        # span tracing (DESIGN.md §13): emitters check tracer.enabled first
        # and reuse timestamps the pipeline already takes, so the disabled
        # cost is one attribute check per site
        self.tracer = tracer
        self._tr_batcher = f"{worker_id}/batcher"
        self._tr_predict = f"{worker_id}/predict"
        self._tr_sender = f"{worker_id}/sender"
        # batcher ring cached once: rings are cleared in place, never
        # replaced, and _flush is too hot for a per-flush locked lookup
        self._tr_batcher_ring = tracer.ring(self._tr_batcher) \
            if tracer is not None else None
        self._dispatch_sem = threading.BoundedSemaphore(self.dispatch_ahead)
        # SimpleQueue (C implementation): per-chunk hand-offs are hot, and
        # depth is already bounded by the dispatch-ahead window (the sem is
        # only released once the sender materializes a chunk)
        self._send_q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._threads: List[threading.Thread] = []
        self._jax_device = device.jax_devices[0] if device.jax_devices else None

        # ---- fault tolerance (DESIGN.md §10) ----
        self._fault = fault_plan         # None on the default hot path
        self.nan_guard = nan_guard
        self._oom_sentinel = oom_sentinel
        self.crashed = threading.Event()   # any stage thread died
        self.crash_cause: Optional[BaseException] = None
        # supervised containment hook: when set, _guarded reports the crash
        # here instead of posting the paper's global {-1, None, None}
        self.on_crash: Optional[Callable[["Worker", BaseException], None]] = None
        # in-flight ledger: (rid, s) -> Request for every descriptor admitted
        # by the batcher but not yet forwarded by the sender.  The sender
        # pops an entry IMMEDIATELY BEFORE posting its completed
        # contribution and skips the post when the pop misses — dict ops
        # are GIL-atomic, so the pop is a perfect mutual-exclusion gate
        # between the sender and a supervisor replaying this worker's
        # in-flight units (replay idempotency; a late wakeup of a stalled
        # quarantined stage can therefore never double-post).
        self._ledger: Dict[tuple, Request] = {}
        # per-stage heartbeats: stage -> [state, perf_counter stamp].  List
        # mutation is GIL-atomic; no lock on the hot path.
        now = time.perf_counter()
        self._hb: Dict[str, list] = {s: [_HB_WAIT, now]
                                     for s in ("batcher", "predictor",
                                               "sender")}

        # preallocated input ring: each slot spans ceil(segment/batch)
        # compiled batches, so one queue hand-off moves a whole segment's
        # worth of coalesced rows through the pipeline (per-batch hand-offs
        # would multiply queue traffic by chunks-per-segment).  The free-list
        # bounds in-flight slots (backpressure).  Mismatched-seq requests
        # draw from a pooled per-width side list instead.
        chunks_per_seg = max(1, -(-segment_size // batch_size))
        self._span = chunks_per_seg * batch_size
        self._ring = [np.zeros((self._span, max_seq), np.int32)
                      for _ in range(RING_SLOTS)]
        self._free_slots: "queue.Queue[int]" = queue.Queue()
        for i in range(len(self._ring)):
            self._free_slots.put(i)
        self._alt_pool: Dict[int, List[np.ndarray]] = {}
        self._alt_lock = threading.Lock()

        try:
            if self._fault is not None:
                self._fault.tick(worker_id, "spawn")
            if self.member_dtype != "fp32" and not fake:
                # quantize host-side BEFORE device_put: the narrow tree
                # (int8/fp8 weights + per-channel scales) is what crosses
                # H2D and what the device holds (~dtype_bytes/4 the fp32
                # footprint); dequantization fuses into the jitted forward
                params = kquant.quantize_params(params, self.member_dtype)
            if self._jax_device is not None:
                params = jax.device_put(params, self._jax_device)
            self.params = params
            self.frontend = None
            if cfg.frontend_tokens:
                fe = frontend if frontend is not None else np.zeros(
                    (batch_size, cfg.frontend_tokens, cfg.fdim), np.float32)
                self.frontend = jnp.asarray(fe)
            donate = jax.default_backend() in ("gpu", "tpu")
            # quantized members feeding a device combiner emit (q, scale)
            # logits for the fused dequant-weight-accumulate epilogue
            self._quant_out = (kquant.is_quantized_dtype(self.member_dtype)
                               and combiner is not None)
            self.predict_fn = make_predict_fn(
                cfg, use_kernel, donate=donate,
                member_dtype=self.member_dtype, quant_out=self._quant_out)
            if not fake:   # warm-up compile so READY means actually servable
                warm = jnp.zeros((batch_size, max_seq), jnp.int32)
                jax.block_until_ready(
                    self.predict_fn(self.params, warm, self.frontend))
            self.prediction_queue.put(Message(seg.READY, model_idx, None))
        except (MemoryError, RuntimeError, ValueError):
            # paper §II.C.2: {-1, None, None} triggers system shutdown.  A
            # controller-initiated speculative spawn passes oom_sentinel=False
            # so a failed probe rejects ONE reconfig action instead of
            # failing every in-flight request (DESIGN.md §8).
            if oom_sentinel:
                self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    # ---- threads -------------------------------------------------------------
    def start(self):
        for fn, name in [(self._batcher, "batcher"), (self._predictor, "predictor"),
                         (self._sender, "sender")]:
            t = threading.Thread(target=self._guarded, args=(fn,),
                                 name=f"{self.worker_id}-{name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _guarded(self, fn):
        """A stage thread dying mid-request would hang its request (and leak
        its in-flight window slot) forever.  Under supervision (``on_crash``
        set) the failure is *contained*: the supervisor quarantines this one
        instance and replays its in-flight work on siblings (DESIGN.md §10).
        Unsupervised, fall back to the paper's {-1, None, None} sentinel,
        which fails every in-flight request and shuts the system down
        (§II.C.2 all-or-nothing semantics, still the default)."""
        try:
            fn()
        except BaseException as e:
            self.crash_cause = e
            self.crashed.set()
            hook = self.on_crash
            if hook is not None:
                try:
                    hook(self, e)
                except Exception:
                    pass          # supervisor loop still sweeps on interval
                return            # contained: no stderr traceback spam
            if self._oom_sentinel:
                self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    def join(self, timeout: float = 30.0) -> List[str]:
        """Join all stage threads against ONE shared deadline (the seed gave
        each thread the full budget — a 3-stage hang took 3x the timeout)
        and report which stages failed to stop instead of silently
        returning; stuck daemons are leaked deliberately (a stalled XLA call
        cannot be interrupted), the caller just must know routing-wise the
        worker is gone but its threads may still wake up later."""
        deadline = time.perf_counter() + timeout
        stuck = []
        for t in self._threads:
            t.join(max(0.0, deadline - time.perf_counter()))
            if t.is_alive():
                stuck.append(t.name)
        if stuck:
            self.timers.inc("join_timeouts", len(stuck))
        return stuck

    def health(self, watchdog_s: float = 5.0) -> int:
        """Liveness verdict for the supervisor: DEAD when a stage thread
        crashed or exited; DEGRADED when a stage has been ACTIVE (mid-work,
        not blocked on an empty queue) longer than ``watchdog_s``; READY
        otherwise.  WAIT-state stamps never age into DEGRADED — an idle
        worker is healthy."""
        if self.crashed.is_set():
            return HEALTH_DEAD
        if self._threads and not all(t.is_alive() for t in self._threads):
            return HEALTH_DEAD
        now = time.perf_counter()
        for state, stamp in self._hb.values():
            if state == _HB_ACTIVE and now - stamp > watchdog_s:
                return HEALTH_DEGRADED
        return HEALTH_READY

    # ---- batch slots ---------------------------------------------------------
    def _effective_linger(self) -> float:
        """Linger budget for a freshly-opened slot.  ``adaptive`` scales the
        configured ``max_wait_us`` down linearly with the input backlog: a
        deep queue means more rows are already on the way (no need to wait
        for them — they arrive this drain) while an idle queue earns the
        full linger to give concurrent requests a chance to coalesce."""
        if self.linger_mode == "adaptive":
            depth = self.input_queue.qsize()
            return self.linger_s * max(0.0, 1.0 - depth / ADAPTIVE_DEPTH)
        return self.linger_s

    def _side_buffer(self, width: int) -> np.ndarray:
        with self._alt_lock:
            pool = self._alt_pool.setdefault(width, [])
            buf = pool.pop() if pool else None
        return buf if buf is not None else \
            np.zeros((self._span, width), np.int32)

    def _open_batch(self, width: int,
                    express: bool = False) -> Optional[_OpenBatch]:
        """Open a fresh slot.  ``express`` (high-priority packing) never
        blocks: it takes a free ring slot if one is instantly available and
        otherwise draws a pooled side buffer — a latency-sensitive request
        must not wait for ``RING_SLOTS`` bulk slots to materialize.  The
        bulk path blocks on the free list (backpressure), but the wait is
        *interruptible*: it returns None the moment high-priority work
        lands in the admission queue, so the batcher can service it first
        (the preemptible-pipeline lever, ROADMAP items e/k)."""
        slot = buf = None
        if width == self._ring[0].shape[1]:
            if express:
                try:
                    slot = self._free_slots.get_nowait()
                except queue.Empty:
                    slot = None
            else:
                while True:
                    try:
                        slot = self._free_slots.get(timeout=0.002)
                        break
                    except queue.Empty:
                        if self.input_queue.depth(seg.PRIORITY_HIGH):
                            return None       # high work first; retry after
            if slot is not None:
                buf = self._ring[slot]
        if buf is None:        # side pool: mismatched seq or express overflow
            slot = None
            buf = self._side_buffer(width)
        return _OpenBatch(slot, buf, width,
                          time.perf_counter() + self._effective_linger())

    def _recycle(self, slot: Optional[int], buf: np.ndarray) -> None:
        if slot is not None:
            self._free_slots.put(slot)
            return
        with self._alt_lock:
            pool = self._alt_pool.setdefault(buf.shape[1], [])
            if len(pool) < ALT_POOL_CAP:
                pool.append(buf)

    # ---- backlog accounting (work stealing, DESIGN.md §8) --------------------
    @property
    def chunks_per_segment(self) -> int:
        """Compiled chunks per full segment (drain-time unit conversion)."""
        return self._span // self.batch_size

    def dispatch_backlog(self) -> int:
        """Chunks flushed but not yet committed to the device — the stage
        the admission-queue depth can no longer see (steal accounting)."""
        return self._dispatch_q.qsize()

    # ---- stage 1: batcher ----------------------------------------------------
    def _flush(self, batch: _OpenBatch) -> None:
        """Close a slot: cut it into compiled-batch chunks (full batches plus
        a pow2-bucketed remainder), zero stale pad rows, and enqueue each
        chunk as an independently schedulable :class:`ChunkDesc` on the
        priority dispatch queue.  The slot's :class:`SlotRef` refcount
        starts at the chunk count, so the ring buffer recycles only after
        every chunk's output is materialized.  Padding counters make
        coalescing efficiency observable."""
        chunks = []                           # (offset, bucket, valid) views
        for off in range(0, batch.fill, self.batch_size):
            valid = min(self.batch_size, batch.fill - off)
            bucket = bucket_for(valid, self.batch_size)
            if valid < bucket:
                batch.buf[off + valid:off + bucket] = 0   # stale tail rows
            chunks.append((off, bucket, valid))
            self.timers.inc("rows_valid", valid)
            self.timers.inc("rows_dispatched", bucket)
        self.timers.inc("batches", len(chunks))
        self.timers.inc("spans", len(batch.spans))
        if not chunks:                        # defensive: nothing packed
            self._recycle(batch.slot, batch.buf)
            return
        ref = SlotRef(batch.slot, batch.buf, len(chunks))
        by_chunk: Dict[int, List[Span]] = {}
        for sp in batch.spans:                # spans are chunk-aligned
            by_chunk.setdefault(sp.batch_off // self.batch_size,
                                []).append(sp)
        now = time.perf_counter()
        by_level: Dict[int, list] = {}
        for i, (off, bucket, valid) in enumerate(chunks):
            spans = by_chunk.get(i, [])
            level = chunk_level(spans)
            by_level.setdefault(level, []).append(
                ChunkDesc(ref, off, bucket, valid, spans, level, now))
        tr = self.tracer
        if tr is not None and tr.enabled:
            # ONE slot-pack instant per flush, stamped with the chunks'
            # shared t_enq — the timestamp the grouped dispatch-round
            # records join against to recover per-chunk request ids, so
            # this is the only place the slot's spans are walked for
            # attribution (batch.spans, not chunks x spans)
            rids = {sp.req.rid for sp in batch.spans}
            self._tr_batcher_ring.append(
                ("i", "pack", now, 0.0,
                 rids.pop() if len(rids) == 1 else tuple(rids),
                 len(chunks), max(by_level), None))
        for level, descs in sorted(by_level.items()):
            self._dispatch_q.put_many(descs, level)

    def _batcher(self):
        open_batch: Optional[_OpenBatch] = None
        hb = self._hb["batcher"]
        while True:
            t0 = time.perf_counter()
            hb[:] = [_HB_WAIT, t0]
            if open_batch is None:
                item = self.input_queue.get()
            else:
                # linger: wait for more rows, bounded by the slot deadline
                wait = open_batch.deadline - time.perf_counter()
                try:
                    if wait > 0:
                        item = self.input_queue.get(timeout=wait)
                    else:
                        item = self.input_queue.get_nowait()
                except queue.Empty:
                    t0 = self.timers.timed("batcher_wait", t0)
                    hb[:] = [_HB_ACTIVE, t0]
                    self._flush(open_batch)   # linger expired
                    open_batch = None
                    self.timers.timed("batch_fill", t0)
                    continue
            t0 = self.timers.timed("batcher_wait", t0)
            hb[:] = [_HB_ACTIVE, t0]
            self.timers.gauge(self._depth_gauge, self.input_queue.qsize())
            if item == SHUTDOWN:
                if open_batch is not None:
                    self._flush(open_batch)
                # a quiesce(wait=True) racing a drain may have enqueued its
                # FlushBarrier behind this SHUTDOWN — release those waiters
                # instead of leaving them to time out (descriptors cannot
                # land here: routing was removed before the SHUTDOWN)
                while True:
                    try:
                        tail = self.input_queue.get_nowait()
                    except queue.Empty:
                        break
                    if isinstance(tail, FlushBarrier):
                        tail.done.set()
                self._dispatch_q.put(None)
                return
            if item == FLUSH or isinstance(item, FlushBarrier):
                if open_batch is not None:    # quiesce: close the open slot
                    self._flush(open_batch)
                    open_batch = None
                if isinstance(item, FlushBarrier):
                    # the barrier rides the dispatch queue: the predictor
                    # acks it only once every chunk flushed before the
                    # quiesce has actually been dispatched (DESIGN.md §8)
                    self._dispatch_q.put(item)
                continue
            open_batch = self._admit(item, open_batch)
            self.timers.timed("batch_fill", t0)

    def _admit(self, item, open_batch: Optional[_OpenBatch]
               ) -> Optional[_OpenBatch]:
        """Pack one (request, segment) descriptor, returning the (possibly
        new / possibly flushed) open batch.  A bulk descriptor's wait for a
        ring slot is preemptible: when high-priority work lands in the
        admission queue mid-wait, the high descriptors are admitted first
        through express side buffers (recursion is one level deep — the
        express path never blocks), then the bulk wait resumes."""
        req, s = item                         # type: Request, int
        if req.dropped():
            # expired/cancelled: never pack rows — fail fast instead of
            # occupying ring slots (idempotent across workers/segments)
            self.prediction_queue.put(Message(
                seg.DROPPED, None, None, rid=req.rid))
            return open_batch
        if req.demoted_for(self.model_idx):
            # demoted mid-flight (brownout, DESIGN.md §11): forgive the
            # unit instead of packing — P=None with s >= 0 debits this
            # member's rows and tracks the missing weight for the
            # completion-time renormalization.  Never DROPPED (that fails
            # the whole request).  Checked BEFORE the ledger add, so no
            # pop-gate is involved: this batcher is the unit's only owner.
            if self.combiner is None or self.combiner.unexpect(req, s):
                lo, hi = req.bounds(s)
                self.timers.inc("rows_demoted", hi - lo)
                self.prediction_queue.put(Message(
                    s, self.model_idx, None, rid=req.rid))
            return open_batch
        # in-flight ledger entry BEFORE any rows are packed: from here the
        # descriptor is this worker's responsibility until the sender (or a
        # replaying supervisor) pops it — the one-statement gap between the
        # admission-queue pop and this add is the only window where a crash
        # loses the unit (hang, bounded by the client deadline, not silent
        # corruption)
        self._ledger[(req.rid, s)] = req
        if self._fault is not None:
            self._fault.tick(self.worker_id, "batcher")
        express = req.priority == seg.PRIORITY_HIGH
        lo, hi = req.bounds(s)
        width = req.x.shape[1]
        pos = lo
        while pos < hi:
            if open_batch is not None and open_batch.width != width:
                self._flush(open_batch)       # can't mix seq widths
                open_batch = None
            if open_batch is None:
                open_batch = self._open_batch(width, express=express)
                if open_batch is None:        # bulk slot wait interrupted
                    # take_high is atomic vs a racing drain_descriptors
                    # (which may empty the queue between a depth check and
                    # a pop) and never swallows sentinels.  A burst of high
                    # descriptors coalesces into ONE express batch (threaded
                    # through the loop) instead of one padded slot each.
                    hot = None
                    while True:
                        hitem = self.input_queue.take_high()
                        if hitem is None:
                            break
                        hot = self._admit(hitem, hot)
                    if hot is not None:       # high work never lingers here
                        self._flush(hot)
                    continue                  # resume the bulk slot wait
            f = open_batch.fill
            fill = min(self._span - f, hi - pos)
            open_batch.buf[f:f + fill] = req.x[pos:pos + fill]    # one copy
            # spans never cross a compiled-batch boundary inside the
            # slot, so every span maps to exactly one predictor chunk
            while fill > 0:
                k = min(self.batch_size - f % self.batch_size, fill)
                open_batch.spans.append(Span(req, s, pos - lo, f, k))
                f += k
                pos += k
                fill -= k
            open_batch.fill = f
            if f == self._span:
                self._flush(open_batch)       # full slot: flush immediately
                open_batch = None
        if open_batch is not None and req.deadline is not None:
            # deadline-aware linger (ROADMAP item f): the slot may wait
            # at most half the tightest packed row's remaining deadline
            # budget — a tight-deadline row never waits out a full
            # linger, and the other half of the budget is left for
            # predict + combine.  Same perf_counter clock as the linger.
            open_batch.deadline = min(
                open_batch.deadline,
                (time.perf_counter() + req.deadline) / 2.0)
        if open_batch is not None and express:
            # high-priority rows preempt the linger: flush as soon as
            # the queue runs dry instead of waiting out max_wait_us
            # (anything already queued still coalesces first)
            open_batch.deadline = 0.0
        if not self.coalesce and open_batch is not None:
            self._flush(open_batch)           # PR-1 semantics: per-item flush
            open_batch = None
        return open_batch

    # ---- stage 2: predictor --------------------------------------------------
    def _predictor(self):
        """Pop chunks from the priority dispatch queue and commit them to
        the device, keeping at most ``dispatch_ahead`` (K) async dispatches
        outstanding.  A window token is acquired *before* each pop, so a
        chunk only leaves the queue when it can dispatch immediately — the
        queue stays free to reorder until the last moment, and K bounds the
        committed (non-preemptible) work.  Dispatched chunks accumulate in
        a local group shipped to the sender in ONE queue hop whenever the
        window fills, the queue runs dry, or a control item arrives —
        per-chunk hand-offs would pay a thread wakeup per chunk
        (chunks-per-slot × the old slot rate) without changing scheduling,
        since the window token is what gates commitment.  A chunk whose
        every span belongs to a cancelled/expired request is never
        dispatched: it rides the group as a skipped chunk (the sender owns
        the staging dict and the DROPPED accounting)."""
        tr = self.tracer
        tr_ring = tr.ring(self._tr_predict) if tr is not None else None
        while True:
            # grab every instantly-available window token (>= 1, blocking
            # for the first) and pop that many chunks in ONE queue lock
            # round — per-chunk lock rounds would pay a contended lock +
            # thread wakeup per chunk with identical commitment semantics,
            # since the token count is what bounds the committed window
            hb = self._hb["predictor"]
            hb[:] = [_HB_WAIT, time.perf_counter()]
            self._dispatch_sem.acquire()
            tokens = 1
            while tokens < self.dispatch_ahead and \
                    self._dispatch_sem.acquire(blocking=False):
                tokens += 1
            items = self._dispatch_q.get_batch(tokens)
            hb[:] = [_HB_ACTIVE, time.perf_counter()]
            group: List[tuple] = []
            committed = 0
            stop = False
            ctl = False                   # round saw a non-chunk item
            t0 = time.perf_counter()
            # double-buffered H2D staging: after committing chunk i, chunk
            # i+1's device_put is issued immediately (device_put is async),
            # so its upload overlaps chunk i's compute instead of
            # serializing upload -> compute per chunk.  One buffer deep:
            # the SlotRef refcount already keeps the staged rows alive
            # (the staged chunk hasn't materialized), and the dispatch
            # window bounds how far ahead staging can run.
            staged = None                 # (ChunkDesc, device buffer)
            stage_h2d = not self.fake and self._jax_device is not None

            def _skippable(c):
                return c.spans and all(
                    sp.req.dropped() or sp.req.demoted_for(self.model_idx)
                    for sp in c.spans)

            def _upload(c):
                view = c.ref.buf[c.off:c.off + c.bucket]
                return jax.device_put(view, self._jax_device)

            for pos, item in enumerate(items):
                if item is None:
                    stop = True
                    ctl = True
                    break
                if isinstance(item, FlushBarrier):
                    if group:         # every earlier chunk is dispatched
                        self._send_q.put(group)
                        group = []
                    item.done.set()
                    ctl = True
                    continue
                chunk: ChunkDesc = item
                self.timers.add("dispatch_wait.high" if chunk.level ==
                                seg.PRIORITY_HIGH else "dispatch_wait.normal",
                                t0 - chunk.t_enq)
                if _skippable(chunk):
                    group.append((chunk, None, t0, True))   # never dispatched
                    continue
                committed += 1
                y = None
                nan_out = False
                if self._fault is not None:
                    nan_out = self._fault.tick(
                        self.worker_id, "predictor") == "nan"
                if nan_out:
                    # poisoned device output: bypasses the real dispatch so
                    # it works identically on fake and real devices; the
                    # sender's nan_guard is what must catch it
                    y = np.full((chunk.bucket, self.num_classes),
                                np.nan, np.float32)
                elif self.fake:
                    if self.fake_delay_us:    # simulated device time
                        time.sleep(self.fake_delay_us * 1e-6)
                else:
                    if staged is not None and staged[0] is chunk:
                        x = staged[1]          # upload already in flight
                        self.timers.inc("h2d_staged", 1)
                    elif self._jax_device is not None:
                        x = _upload(chunk)
                    else:
                        x = jnp.asarray(
                            chunk.ref.buf[chunk.off:chunk.off + chunk.bucket])
                    staged = None
                    fe = (self.frontend[:chunk.bucket]
                          if self.frontend is not None else None)
                    y = self.predict_fn(self.params, x, fe)  # async dispatch
                    if stage_h2d:
                        # overlap the NEXT chunk's upload with this compute
                        for nxt in items[pos + 1:]:
                            if nxt is None or isinstance(nxt, FlushBarrier):
                                break
                            if not _skippable(nxt):
                                staged = (nxt, _upload(nxt))
                                break
                group.append((chunk, y, t0, False))
            for _ in range(tokens - committed):   # unused / skipped tokens
                self._dispatch_sem.release()
            if group:
                self._send_q.put(group)
            if committed:
                t1 = self.timers.timed("predict", t0)
            if tr is not None and tr.enabled and items:
                # ONE flat rid-free record per pop round (invisible to
                # the GC), with ZERO per-chunk work in the loop above:
                # the popped list is reused as the round's chunk group
                # (filtered only when a control item rode along — rare).
                # dur slot = absolute pop time, slot a = the packed
                # per-chunk enqueue times, slots b/c = the attached
                # predict duration / committed count.  Request
                # attribution is recovered at export time by joining
                # each t_enq against this worker's pack instants, so the
                # hot loop never walks span lists.
                dw = items if not ctl else \
                    [c for c in items if isinstance(c, ChunkDesc)]
                if dw:
                    tr_ring.append(
                        ("G", "dispatch_wait", dw[0].t_enq, t0, None,
                         pack_times([c.t_enq for c in dw]),
                         (t1 - t0) if committed else None,
                         committed or None))
            if stop:
                self._send_q.put(None)
                return

    # ---- stage 3: sender -----------------------------------------------------
    def _sender(self):
        """Materialize each chunk's output and scatter its spans back to
        their segments, forwarding a (request, segment) contribution **as
        soon as its last span's chunk returns** — early per-segment
        forwarding; the whole slot no longer has to retire first.  All of a
        segment's spans still pass through THIS sender (the broadcaster
        assigns every (segment, model) pair to one instance), but priority
        reordering in the dispatch queue means they may arrive out of
        seg_off order, so staging is row-count-based with parts keyed by
        segment offset; downstream accounting already counts rows.  Still
        ONE contribution per (request, segment) — per-span forwarding would
        multiply combiner/accumulator traffic by chunks-per-segment and
        serialize senders on the combiner lock.  The sender also owns the
        DROPPED path: spans of cancelled/expired requests (and whole
        skipped chunks) purge their staging entry and post the rows to the
        ``rows_dropped`` counter, keyed by an idempotent ``DROPPED``
        resolution message."""
        on_device = self.combiner is not None
        staging: Dict[tuple, list] = {}     # (rid, s) -> [rows, {seg_off: P}]
        tr = self.tracer
        tr_ring = tr.ring(self._tr_sender) if tr is not None else None
        hb = self._hb["sender"]
        while True:
            hb[:] = [_HB_WAIT, time.perf_counter()]
            batch = self._send_q.get()
            if batch is None:
                return
            t0 = time.perf_counter()
            hb[:] = [_HB_ACTIVE, t0]
            profiled = []                  # (bucket, valid) materialized
            for chunk, y, t_dispatch, skipped in batch:
                self._send_chunk(chunk, y, skipped, staging, on_device,
                                 profiled)
            now = self.timers.timed("transfer", t0)   # sync+scatter, group
            if tr is not None and tr.enabled:
                # grouped single span: slot a carries the group's shared
                # dispatch (pop) time — the correlation key export joins
                # against this worker's "G" dispatch-round record (which
                # in turn joins the pack instants) to recover request
                # ids, so the sender packs nothing per chunk
                tr_ring.append(
                    ("g", "transfer", t0, now - t0, None,
                     batch[0][2], len(batch), None))
            if profiled:
                # live bench feed (DESIGN.md §8): the group shares one
                # dispatch timestamp, so dispatch-to-materialized wall time
                # is attributed to its chunks proportionally by dispatched
                # rows — charging each chunk the cumulative group elapsed
                # would inflate the profile by up to dispatch_ahead x
                dt = now - batch[0][2]
                total = sum(b for b, _ in profiled) or 1
                for bucket, valid in profiled:
                    self.profiler.observe(self.model_idx, self.device.key(),
                                          bucket, valid, dt * bucket / total)

    def _send_chunk(self, chunk, y, skipped, staging, on_device, profiled):
        if not skipped:
            if self._fault is not None:
                self._fault.tick(self.worker_id, "sender")
            if y is not None:
                if on_device:
                    if isinstance(y, np.ndarray):    # injected NaN output
                        pass
                    else:
                        # (q, scale) tuples from quantized members block as
                        # a pytree; compute done, arrays stay on device
                        jax.block_until_ready(y)
                else:
                    y = np.asarray(y)      # d->h sync
                if self.nan_guard and isinstance(y, np.ndarray) \
                        and np.isnan(y).any():
                    # poisoned output: dying here (WorkerCrashed through
                    # _guarded) routes recovery through quarantine + replay
                    # on a sibling rather than folding NaN into Y
                    raise seg.WorkerCrashed(
                        f"{self.worker_id}: NaN in device output")
            self._dispatch_sem.release()   # window slot free again
            if self.profiler is not None and (y is not None
                                              or self.fake_delay_us):
                profiled.append((chunk.bucket, chunk.valid))
        if chunk.ref.release():            # last outstanding chunk:
            self._recycle(chunk.ref.slot, chunk.ref.buf)   # recycle slot
        dropped_rids = set()
        for sp in chunk.spans:
            lo, hi = sp.req.bounds(sp.s)
            key = (sp.req.rid, sp.s)
            if sp.req.demoted_for(self.model_idx) and not sp.req.dropped():
                # demoted mid-flight (brownout, DESIGN.md §11): discard any
                # staged rows and forgive the whole segment behind the
                # ledger pop-gate (exactly once vs a replaying supervisor
                # — same gate as the forwarding path).  Checked BEFORE the
                # dropped branch: a chunk skipped because its spans are
                # demoted must forgive, never DROPPED-fail the request.
                staging.pop(key, None)
                self.timers.inc("rows_demoted", sp.n)
                if self._ledger.pop(key, None) is not None and (
                        self.combiner is None or
                        self.combiner.unexpect(sp.req, sp.s)):
                    self.prediction_queue.put(Message(
                        sp.s, self.model_idx, None, rid=sp.req.rid))
                    tr = self.tracer
                    if tr is not None and tr.enabled:
                        tr.ring(self._tr_sender).append(
                            ("i", "forgive_demoted", tr.clock(), 0.0,
                             sp.req.rid, sp.s, None, None))
                continue
            if skipped or sp.req.dropped():
                # purge any rows staged by this segment's earlier chunks
                # (whatever order the chunks retired in, its LAST chunk
                # runs this branch too, so no entry can leak) and post
                # the idempotent DROPPED resolution
                staging.pop(key, None)
                self._ledger.pop(key, None)
                self.timers.inc("rows_dropped", sp.n)
                if sp.req.rid not in dropped_rids:
                    dropped_rids.add(sp.req.rid)
                    self.prediction_queue.put(Message(
                        seg.DROPPED, None, None, rid=sp.req.rid))
                    tr = self.tracer
                    if tr is not None and tr.enabled:
                        tr.ring(self._tr_sender).append(
                            ("i", "dropped", tr.clock(), 0.0,
                             sp.req.rid, sp.s, None, None))
                continue
            st = staging.get(key)
            if st is None:
                st = staging[key] = [0, {}]
            if y is not None:
                off = sp.batch_off - chunk.off   # row within this chunk
                if isinstance(y, tuple):   # quantized (q, per-row scale)
                    st[1][sp.seg_off] = (y[0][off:off + sp.n],
                                         y[1][off:off + sp.n])
                else:
                    st[1][sp.seg_off] = y[off:off + sp.n]
            st[0] += sp.n
            if st[0] < hi - lo:
                continue                   # segment still in flight
            del staging[key]
            # pop-gate (DESIGN.md §10): claim the in-flight ledger entry
            # IMMEDIATELY before forwarding.  dict.pop is GIL-atomic, so
            # exactly one of {this sender, a supervisor replaying this
            # worker} wins the entry — a miss means the unit was already
            # resubmitted to a sibling (this worker was quarantined, e.g.
            # a stalled stage waking up late) and forwarding it again
            # would double-count rows into Y.  Popping BEFORE the post
            # (not after) means a crash inside the post window hangs the
            # unit (bounded by deadline / retry) instead of corrupting Y.
            if self._ledger.pop(key, None) is None:
                continue
            # no forward instant here: the pop-gate moment is already
            # observable as the downstream combine/accumulate span for
            # (rid, s), and this path runs per (segment, member) — hot
            # enough that an extra clock call + emit showed up in the
            # tracing_overhead gate
            if y is None and not st[1]:    # fake predictor: instant zeros
                P = np.zeros((hi - lo, self.num_classes), np.float32)
            else:
                parts = [st[1][k] for k in sorted(st[1])]
                if len(parts) == 1:
                    P = parts[0]
                elif isinstance(parts[0], tuple):   # quantized parts
                    P = (jnp.concatenate([p[0] for p in parts], axis=0),
                         jnp.concatenate([p[1] for p in parts], axis=0))
                elif on_device:
                    P = jnp.concatenate(parts, axis=0)
                else:
                    P = np.concatenate(parts, axis=0)
            if on_device:
                self.combiner.add(sp.req, sp.s, self.model_idx, P)
            else:
                self.prediction_queue.put(Message(
                    sp.s, self.model_idx, np.asarray(P),
                    rid=sp.req.rid))
