"""hymba-1.5b [hybrid] — parallel attention + Mamba heads in every block,
sliding-window on the attention heads.  [arXiv:2411.13676]"""
from repro.configs.base import HYBRID, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    pattern=(HYBRID,),
    sliding_window=1024,          # attention heads are windowed (3 global in the
                                  # source model; we window all for sub-quadratic decode)
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4, chunk=64),
    rope_theta=10000.0,
    vocab_pad_to=2048,            # 32001 -> 32768
    source="arXiv:2411.13676",
)
