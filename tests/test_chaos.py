"""Chaos suite (DESIGN.md §10): deterministic fault injection against the
supervision / quarantine / replay / degradation machinery.

Every test runs on simulated (``fake``) or host-CPU devices with a
:class:`FaultPlan` arming exactly one failure, so each recovery path is
exercised at a reproducible pipeline position:

  * killing one data-parallel sibling mid-trace loses zero requests, and
    replayed chunks produce **bit-identical** results vs a fault-free run;
  * killing a member's only instance completes open requests with a
    degraded-quality partial combine — never a hang, never the global
    shutdown — and the controller respawns the member in background;
  * the global {-1, None, None} sentinel fires only for the last instance
    of the last member;
  * stalls are caught by the watchdog, spawn failures back off, retry
    budgets bound replay, NaN outputs crash their worker instead of
    folding into Y.
"""
import time

import jax
import numpy as np
import pytest

from repro import models as M
from repro.configs import ensemble
from repro.core.allocation import AllocationMatrix
from repro.core.devices import host_cpus
from repro.serving.control import ReconfigController
from repro.serving.faults import FaultPlan, FaultSpec, InjectedFault
from repro.serving.segments import MemberUnavailable, RetriesExhausted
from repro.serving.system import InferenceSystem
from repro.serving.worker import HEALTH_DEAD, HEALTH_READY

pytestmark = pytest.mark.chaos

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    A = np.array(A)
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    kw.setdefault("max_seq", SEQ)
    kw.setdefault("supervise", True)
    kw.setdefault("supervise_interval_s", 0.02)
    return InferenceSystem(cfgs, params, alloc, **kw)


def _X(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 64, (n, SEQ)).astype(np.int32)


# ---- FaultPlan mechanics -----------------------------------------------------

def test_fault_spec_parse_and_validation():
    s = FaultSpec.parse("stage=predictor,kind=stall,after=3,stall_s=1.5,"
                        "worker=w0.0")
    assert (s.stage, s.kind, s.after, s.stall_s, s.worker) == \
        ("predictor", "stall", 3, 1.5, "w0.0")
    with pytest.raises(ValueError):
        FaultSpec.parse("kind=raise")             # stage required
    with pytest.raises(ValueError):
        FaultSpec.parse("stage=predictor,bogus=1")
    with pytest.raises(ValueError):
        FaultSpec(stage="sender", kind="nan")     # nan is predictor-only
    with pytest.raises(ValueError):
        FaultSpec(stage="nope")


def test_fault_plan_counts_and_fires_once():
    fp = FaultPlan(FaultSpec(stage="sender", after=2, worker="w0"))
    assert fp.tick("w1", "sender") is None        # wrong worker prefix
    assert fp.tick("w0", "sender") is None        # unit 0
    assert fp.tick("w0", "sender") is None        # unit 1
    with pytest.raises(InjectedFault):
        fp.tick("w0", "sender")                   # unit 2 fires
    assert fp.tick("w0", "sender") is None        # one-shot: never again
    assert fp.fired == [("w0", "sender", "raise")]


# ---- zero-loss sibling recovery ----------------------------------------------

@pytest.mark.parametrize("stage", ["batcher", "predictor", "sender"])
def test_sibling_kill_loses_zero_requests(ens2, stage):
    """Killing one of two data-parallel siblings mid-trace: every request
    completes at full quality, via replay on the surviving sibling."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage=stage, kind="raise", after=1,
                             worker="w1.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp)
    try:
        hs = [s.predict_async(_X(48, seed=i)) for i in range(10)]
        Ys = [h.result(60.0) for h in hs]
        assert all(y.shape == (48, cfgs[0].vocab_size) for y in Ys)
        assert all(h.quality == 1.0 for h in hs)
        c = s.serving_counters()
        assert c.get("quarantines") == 1
        assert c.get("worker_crashes") == 1
        # the dead sibling left routing; member 0 still has w0.0
        assert [w.worker_id for w in s.instances(0)] == ["w0.0"]
        # and the system still serves new requests
        assert s.predict(_X(16), timeout=60.0).shape[0] == 16
    finally:
        s.shutdown()


def test_sibling_kill_bit_identical_replay(ens2):
    """Replayed chunks re-run the same compiled fn at the same batch shape
    on identical rows — results match a fault-free run bit for bit."""
    cfgs, params = ens2
    A = [[8, 8], [8, 0]]                  # m0: siblings w0.0/w1.0, equal b=8
    Xs = [_X(8, seed=i) for i in range(8)]

    def run(fault_plan):
        # generous watchdog: real-model compiles under CPU contention must
        # not read as stalls and quarantine a healthy worker
        s = make_system(cfgs, params, A, segment_size=8, watchdog_s=60.0,
                        fault_plan=fault_plan)
        try:
            hs = [s.predict_async(x) for x in Xs]
            return [np.array(h.result(120.0)) for h in hs], \
                [h.quality for h in hs]
        finally:
            s.shutdown()

    base, _ = run(None)
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=1,
                             worker="w1.0"))
    faulted, quals = run(fp)
    assert all(q == 1.0 for q in quals)
    for i, (yb, yf) in enumerate(zip(base, faulted)):
        np.testing.assert_array_equal(yb, yf, err_msg=f"request {i}")


def test_stall_detected_and_quarantined(ens2):
    """A stage stuck mid-work past the watchdog is DEGRADED -> quarantined;
    the stalled thread's late wakeup is gated by the ledger pop (no
    double-posts, so every request still completes exactly once)."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="stall", after=1,
                             stall_s=2.0, worker="w1.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp, watchdog_s=0.2)
    try:
        hs = [s.predict_async(_X(48, seed=i)) for i in range(10)]
        Ys = [h.result(60.0) for h in hs]
        assert all(y.shape[0] == 48 for y in Ys)
        c = s.serving_counters()
        assert c.get("stalls_detected") >= 1
        assert c.get("quarantines") == 1
        time.sleep(2.2)                   # let the stalled thread wake up
        assert s.predict(_X(16), timeout=60.0).shape[0] == 16
    finally:
        s.shutdown()


def test_nan_guard_recovers_on_sibling(ens2):
    """An injected NaN output crashes its worker (WorkerCrashed through the
    guard) and the chunk replays cleanly on the sibling."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="nan", after=0,
                             worker="w1.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp, nan_guard=True)
    try:
        hs = [s.predict_async(_X(48, seed=i)) for i in range(8)]
        for h in hs:
            assert not np.isnan(h.result(60.0)).any()
        c = s.serving_counters()
        assert c.get("worker_crashes") == 1 and c.get("quarantines") == 1
    finally:
        s.shutdown()


def test_retry_budget_exhaustion(ens2):
    """With retry_budget=0, the first quarantine that touches a request's
    in-flight work fails it with RetriesExhausted instead of replaying."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=0,
                             worker="w1.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=2000, fault_plan=fp, retry_budget=0)
    try:
        hs = [s.predict_async(_X(48, seed=i)) for i in range(8)]
        outcomes = set()
        for h in hs:
            try:
                h.result(60.0)
                outcomes.add("ok")
            except RetriesExhausted:
                outcomes.add("exhausted")
        assert "exhausted" in outcomes    # at least the in-flight ones
    finally:
        s.shutdown()


# ---- graceful degradation ----------------------------------------------------

def test_sole_instance_death_degrades_not_hangs(ens2):
    """Killing a member's ONLY instance completes open requests with a
    partial-ensemble combine (quality < 1, renormalized over survivors) —
    never a hang, never a global shutdown."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="batcher", kind="raise", after=1,
                             worker="w0.1"))    # m1's sole instance
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=500, fault_plan=fp)
    try:
        hs = []
        for i in range(8):
            try:
                hs.append(s.predict_async(_X(48, seed=i)))
            except MemberUnavailable:
                break                     # crash landed mid-loop: fail-fast
        assert hs                         # at least one request got in
        Ys = [h.result(60.0) for h in hs]     # nothing hangs
        assert all(y.shape[0] == 48 for y in Ys)
        quals = [h.quality for h in hs]
        assert any(q < 1.0 for q in quals)    # open requests degraded
        assert all(0.0 < q <= 1.0 for q in quals)
        c = s.serving_counters()
        assert c.get("degraded_requests") >= 1
        # new full-ensemble submits fail fast with the retryable error...
        with pytest.raises(MemberUnavailable):
            s.predict(_X(8), timeout=10.0)
        # ...but the surviving member still serves
        assert s.predict(_X(16), timeout=60.0,
                         members=[0]).shape[0] == 16
    finally:
        s.shutdown()


def test_degraded_renormalization_weights(ens2):
    """Degraded rows renormalize over surviving members: with fake workers
    member predictions are all-zeros, so Y is zero either way — instead
    verify quality accounting matches the lost fraction exactly."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="batcher", kind="raise", after=0,
                             worker="w0.1"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=500, fault_plan=fp)
    try:
        h = s.predict_async(_X(48))
        h.result(60.0)
        if h.quality < 1.0:               # the open request lost member 1
            assert h.quality == pytest.approx(0.5)
    finally:
        s.shutdown()


def test_member_respawn_in_background(ens2):
    """After a sole-instance death the controller respawns the member with
    backoff; full-ensemble serving resumes."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="batcher", kind="raise", after=0,
                             worker="w0.1"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp)
    ctl = ReconfigController(s, replan=False, steal=True).start()
    try:
        try:
            s.predict(_X(32), timeout=30.0)
        except MemberUnavailable:
            pass
        deadline = time.perf_counter() + 15.0
        while not s.instances(1) and time.perf_counter() < deadline:
            time.sleep(0.05)
        assert s.instances(1), "member 1 was not respawned"
        assert s.predict(_X(32), timeout=60.0).shape[0] == 32
        assert ctl.stats()["counters"]["respawns"] == 1
    finally:
        ctl.stop()
        s.shutdown()


def test_last_member_last_instance_fires_global_sentinel(ens2):
    """With ONE member on ONE instance, its death leaves nothing to degrade
    onto: the paper's global {-1, None, None} semantics apply."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="batcher", kind="raise", after=0))
    s = make_system(cfgs[:1], params[:1], [[8]], fake=True,
                    fake_delay_us=500, fault_plan=fp)
    try:
        h = s.predict_async(_X(48))
        with pytest.raises(MemoryError):
            h.result(30.0)
    finally:
        s.shutdown()


# ---- supervision plumbing ----------------------------------------------------

def test_spawn_fault_and_controller_backoff(ens2):
    """A failed speculative spawn counts, backs off exponentially, and is
    not re-attempted until the backoff expires."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="spawn", kind="raise", worker="w1.1"))
    s = make_system(cfgs, params, [[8, 8], [0, 0]], fake=True,
                    supervise=False, fault_plan=fp)
    ctl = ReconfigController(s, replan=False, steal=False)
    try:
        gen = s.generation + 1
        assert ctl._spawn(1, 1, 8, gen) is False      # injected spawn fault
        assert ctl.counters["spawn_failures"] == 1
        # the spec is one-shot, so a retry would succeed — but the backoff
        # must skip it without attempting
        assert ctl._spawn(1, 1, 8, gen) is False
        assert ctl.counters["spawn_failures"] == 1    # skipped, not failed
        ctl._backoff[(1, 1)][1] = 0.0                 # force-expire backoff
        assert ctl._spawn(1, 1, 8, gen) is True
        assert (1, 1) not in ctl._backoff             # success clears it
    finally:
        s.shutdown()


def test_join_reports_stuck_threads(ens2):
    """Worker.join must name the stage threads that failed to stop instead
    of silently returning (satellite fix)."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="stall", after=0,
                             stall_s=2.5, worker="w0.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=100, fault_plan=fp, watchdog_s=0.2)
    try:
        stalled = next(w for w in s.workers if w.worker_id == "w0.0")
        s.predict_async(_X(16))
        deadline = time.perf_counter() + 10.0
        while time.perf_counter() < deadline:
            if s.serving_counters().get("quarantines"):
                break
            time.sleep(0.05)
        live_ids = {w.worker_id for w in s.workers}
        assert "w0.0" not in live_ids     # quarantined out of routing
        # the predictor is asleep inside the injected stall: a bounded join
        # must come back and say so, not hang or lie
        stuck = stalled.join(timeout=0.2)
        assert any("predictor" in name for name in stuck)
        assert s.serving_counters().get("join_timeouts") >= 1
    finally:
        s.shutdown()


def test_health_gauges_exported(ens2):
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=0,
                             worker="w1.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp)
    try:
        hs = [s.predict_async(_X(48, seed=i)) for i in range(6)]
        for h in hs:
            h.result(60.0)
        g = s.serving_gauges()
        assert g["health.w0.0"]["last"] == HEALTH_READY
        assert g["health.w0.1"]["last"] == HEALTH_READY
        assert g["health.w1.0"]["last"] == HEALTH_DEAD   # quarantined
    finally:
        s.shutdown()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_unsupervised_keeps_paper_semantics(ens2):
    """Without supervision the seed behavior is unchanged: a worker crash
    posts the global OOM sentinel and fails every in-flight request."""
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=0,
                             worker="w0.0"))
    s = make_system(cfgs, params, [[8, 8], [8, 0]], fake=True,
                    fake_delay_us=300, fault_plan=fp, supervise=False)
    try:
        h = s.predict_async(_X(48))
        with pytest.raises(MemoryError):
            h.result(30.0)
    finally:
        s.shutdown()
