"""Serving-path correctness: prefill + decode_step must reproduce the full
forward pass for every architecture family (KV ring buffers, SSM states,
cross-attn caches)."""
import jax
import jax.numpy as jnp
import pytest

import repro.models as M
from repro.configs import get_config, list_architectures


@pytest.mark.parametrize("arch", list_architectures())
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    B, S, extra = 2, 24, 4
    tokens = jax.random.randint(rng, (B, S + extra), 0, cfg.vocab_size)
    fe = (jnp.ones((B, cfg.frontend_tokens, cfg.fdim)) * 0.1
          if cfg.frontend_tokens else None)
    logits_full, _ = M.forward(params, cfg, tokens, fe)
    scale = float(jnp.abs(logits_full).max())

    lg, cache = M.prefill(params, cfg, tokens[:, :S], 64, fe)
    errs = [float(jnp.abs(lg - logits_full[:, S - 1]).max())]
    for t in range(extra):
        lg, cache = M.decode_step(params, cfg, cache,
                                  tokens[:, S + t:S + t + 1], jnp.int32(S + t))
        errs.append(float(jnp.abs(lg - logits_full[:, S + t]).max()))
    assert max(errs) < 1e-3 * max(scale, 1.0), (arch, errs)


def test_swa_ring_buffer_wraps():
    """Decode far past the window: ring buffer must stay exact."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window == 64
    # shrink the window below sequence length to force wrapping
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=16)
    rng = jax.random.PRNGKey(1)
    params = M.init_params(rng, cfg)
    B, total = 1, 40
    tokens = jax.random.randint(rng, (B, total), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, tokens)
    S = 8
    lg, cache = M.prefill(params, cfg, tokens[:, :S], 64)
    for t in range(S, total):
        lg, cache = M.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                  jnp.int32(t))
        err = float(jnp.abs(lg - logits_full[:, t]).max())
        assert err < 1e-3, (t, err)


def test_decode_with_pallas_kernels():
    """The Pallas decode path (interpret mode) matches the jnp path."""
    cfg = get_config("qwen3-1.7b").reduced()
    rng = jax.random.PRNGKey(2)
    params = M.init_params(rng, cfg)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, tokens[:, :8], 32)
    _, cache_k = M.prefill(params, cfg, tokens[:, :8], 32)
    lg1, _ = M.decode_step(params, cfg, cache, tokens[:, 8:9], jnp.int32(8))
    lg2, _ = M.decode_step(params, cfg, cache_k, tokens[:, 8:9], jnp.int32(8),
                           use_kernel=True)
    assert float(jnp.abs(lg1 - lg2).max()) < 2e-3
