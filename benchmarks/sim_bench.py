"""Discrete-event serving-simulator benchmarks (ISSUE 8, DESIGN.md §12).

The simulator (``repro.serving.sim``) runs the *real* policy code —
admission ordering, the chunk-granular dispatch queue, work stealing,
brownout, LiveBench, the bounded-greedy replanner — under a virtual clock
with per-member service-time models, so serving questions that would take
minutes of wall time (and a noisy host) resolve in seconds, exactly
reproducibly.  Scenarios:

  * ``scale``       replay throughput: a Poisson trace through a 4-worker
                    system, single process.  Default 250k requests in CI
                    (``SIM_SCALE_REQUESTS=1000000`` reproduces the
                    acceptance demonstration); ``scale_ok`` gates the
                    ISSUE-8 bar — a 1M-request replay must fit in 60 s, so
                    the measured rate must hold >= 1e6/60 req/s —
                    plus full completion.  The same pass replays a 20k
                    prefix twice and diffs the event logs + results for
                    the bit-identical determinism guarantee
                    (``determinism_ok``);
  * ``forecast_replan``  the planning workload (ROADMAP item j): diurnal
                    antiphase demand across two members on three devices
                    at ~0.8 mean utilization — each half-cycle the hot
                    member needs 2 of the 3 devices, so a replanner fed a
                    *trailing* demand EWMA flips allocations after the
                    wave has already built backlog.  Runs the identical
                    trace with the bounded greedy scoring the LiveBench
                    EWMA vs the linear-trend forecaster feeding
                    ``LiveBench.set_forecast`` ahead of each replan;
                    ``p99_improvement`` (EWMA p99 / forecast p99) gates
                    that planning against *predicted* shares beats
                    planning against trailing ones;
  * ``ktuner``      the dispatch-ahead auto-tuner (ROADMAP item l) on a
                    saturated bulk trace with per-group overhead h=0.2 ms
                    and per-chunk service s=1.0 ms: throughput follows
                    K/(h + K*s), and the smallest K within 1% of the best
                    is 16 — the tuner must reproduce the live engine's
                    known-good ``DISPATCH_AHEAD`` default
                    (``recommended_ok``);
  * ``edf``         the prototype chunk-level EDF scheduler (ROADMAP item
                    m, ``EDFDispatchQueue``): bursts sized to the ring
                    window where two tight-deadline requests arrive buried
                    behind loose ones.  FIFO serves them in arrival order
                    and misses; EDF pops earliest-absolute-deadline chunks
                    first and meets every deadline on the identical trace
                    (``miss_reduction`` = 1 - EDF misses / FIFO misses).

Acceptance (ISSUE 8): >= 1M synthetic requests replay in < 60 s
single-process (``scale.scale_ok``); forecast-fed replanning beats
EWMA-fed on the diurnal trace (``forecast_replan.p99_improvement``, floor
1.2x); the tuner reproduces K=16 on the throughput trace
(``ktuner.recommended_ok``) — all gated by check_regression.py.  The
sim-vs-real calibration gate lives in the serving bench
(``serving_hotpath.py --scenario sim_fidelity``).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.core.greedy import bounded_greedy
from repro.serving.admission import EDFDispatchQueue
from repro.serving.control import LiveBench
from repro.serving.sim import (DemandForecaster, ServiceModel, SimSystem,
                               WorkerSpec, diurnal_trace, poisson_trace,
                               tune_dispatch_ahead)
from repro.serving.trace import TraceEvent

GiB = 1024 ** 3

# the ISSUE-8 scale bar: 1M requests in < 60 s single-process
SCALE_RATE_FLOOR = 1e6 / 60.0


def _scale_system(svc):
    return SimSystem(svc, [WorkerSpec(0, 64), WorkerSpec(0, 64),
                           WorkerSpec(1, 64), WorkerSpec(1, 64)],
                     segment_size=64, max_wait_us=500.0)


def _measure_scale(requests: int, seed: int) -> dict:
    """Replay throughput + the bit-identical determinism guarantee."""
    svc = ServiceModel.from_delays({0: 200, 1: 200})
    trace = poisson_trace(requests, rate=120_000.0, seed=seed, rows=8,
                          members_choices=[(0,), (1,)])
    sim = _scale_system(svc)
    t0 = time.perf_counter()
    sim.run(trace)
    dt = time.perf_counter() - t0
    r = sim.results()
    rate = requests / dt
    out = {
        "requests": requests,
        "replay_seconds": dt,
        "replay_req_per_s": rate,
        "completed": r["completed"],
        "failed": r["failed"],
        "p99_ms": r["p99_ms"],
        "scale_ok": float(rate >= SCALE_RATE_FLOOR and
                          r["completed"] == requests),
    }
    # determinism: same seed + trace -> bit-identical event log and results
    logs, metrics = [], []
    for _ in range(2):
        s2 = SimSystem(svc, [WorkerSpec(0, 64), WorkerSpec(0, 64),
                             WorkerSpec(1, 64), WorkerSpec(1, 64)],
                       segment_size=64, max_wait_us=500.0,
                       record_events=True)
        s2.run(trace[:20_000])
        logs.append(tuple(s2.event_log))
        metrics.append(s2.results())
    out["determinism_ok"] = float(logs[0] == logs[1]
                                  and metrics[0] == metrics[1])
    out["determinism_events"] = len(logs[0])
    return out


def _measure_forecast_replan(seed: int) -> dict:
    """EWMA-fed vs forecast-fed bounded-greedy replanning on the identical
    diurnal trace.  Each (member, device) placement is pre-calibrated into
    the LiveBench so the greedy scores every neighbour from measurements
    (a cold placement would fall back to the analytic roofline, which has
    nothing to do with the simulated service model)."""
    cfgs = ensemble("ENS4")[:2]
    devs = host_cpus(3, memory_bytes=8 * GiB)
    A0 = np.array([[64, 0], [64, 0], [0, 64]])
    svc = ServiceModel.from_delays({0: 4000, 1: 4000})
    # 3 devices x 16k rows/s, mean offered 4800 req/s x 8 rows = 0.8 util;
    # amplitude 0.4 swings each member between 10% and 90% of demand
    trace = diurnal_trace(19_200, seed=seed, rate=4800.0, period_s=2.0,
                          amplitude=0.4, rows=8,
                          members_groups=((0,), (1,)))
    out = {}
    for mode in ("ewma", "forecast"):
        alloc = AllocationMatrix(devs, [c.name for c in cfgs], A0.copy())
        live = LiveBench(cfgs, seq=16)
        for m in range(len(cfgs)):
            for d in devs:
                for _ in range(8):
                    live.observe(m, d.key(), 64, 64, 0.004)
        sim = SimSystem.from_alloc(alloc, svc, segment_size=64, live=live,
                                   max_wait_us=500)
        fc = DemandForecaster(len(cfgs), bin_s=0.1, trend_bins=4)
        if mode == "forecast":
            sim.forecaster = fc
        applied = [0]

        def replan(s, fc=fc, live=live, mode=mode, applied=applied):
            if mode == "forecast":
                fc.feed(live, lead_s=0.35, ttl_s=0.6)
            prop, _ = bounded_greedy(s.alloc, live, max_iter=3,
                                     max_neighs=60, batch_sizes=(64,),
                                     seed=0)
            if live(prop) > live(s.alloc) * 1.005:
                s.apply_alloc(prop)
                applied[0] += 1

        sim.add_control(0.25, replan, phase_s=0.25)
        sim.run(trace)
        r = sim.results()
        out[mode] = {"p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
                     "completed": r["completed"], "failed": r["failed"],
                     "replans_applied": applied[0],
                     "throughput_rows_per_s": r["throughput_rows_per_s"]}
    out["p99_improvement"] = (out["ewma"]["p99_ms"] /
                              max(out["forecast"]["p99_ms"], 1e-9))
    out["p50_improvement"] = (out["ewma"]["p50_ms"] /
                              max(out["forecast"]["p50_ms"], 1e-9))
    return out


def _measure_ktuner(seed: int) -> dict:
    """Sweep the dispatch-ahead window on a saturated bulk trace; the
    throughput objective must land on the live default (16)."""
    svc = ServiceModel.from_delays({0: 1000},
                                   dispatch_overhead_s=2e-4)
    trace = poisson_trace(400, rate=1e6, seed=seed, rows=64,
                          members_choices=[(0,)])

    def make_sim(k):
        return SimSystem(svc, [WorkerSpec(0, 8)], segment_size=64,
                         dispatch_ahead=k, max_wait_us=100)

    out = tune_dispatch_ahead(make_sim, trace, ks=(1, 2, 4, 8, 16, 32))
    out["recommended_ok"] = float(out["recommended"] == 16)
    return out


def _measure_edf() -> dict:
    """Deadline-mixed bursts through the FIFO dispatch queue vs the EDF
    prototype; the trace is deterministic by construction (no RNG)."""
    svc = ServiceModel.from_delays({0: 2000})
    events = []
    for b in range(40):
        t = b * 0.012          # 8 ms of service every 12 ms: drains fully
        for i in range(4):     # burst fits the 4-slot ring window
            dl = 7.0 if i >= 2 else 400.0
            events.append(TraceEvent(t=t + i * 1e-5, rows=64,
                                     deadline_ms=dl, members=(0,)))
    out = {}
    for name, qcls in (("fifo", None), ("edf", EDFDispatchQueue)):
        kw = {"queue_cls": qcls} if qcls else {}
        sim = SimSystem(svc, [WorkerSpec(0, 64)], segment_size=64,
                        dispatch_ahead=1, max_wait_us=100, **kw)
        sim.run(events)
        r = sim.results()
        out[name] = {"completed": r["completed"], "failed": r["failed"],
                     "deadline_misses": r["deadline_misses"],
                     "p99_ms": r["p99_ms"]}
    fifo, edf = (out["fifo"]["deadline_misses"],
                 out["edf"]["deadline_misses"])
    out["miss_reduction"] = (1.0 - edf / fifo) if fifo else 0.0
    return out


def run(csv=True, scale_requests=None, seed=7):
    if scale_requests is None:
        scale_requests = int(os.environ.get("SIM_SCALE_REQUESTS", 250_000))
    results = {"rng_seed": seed}
    results["scale"] = _measure_scale(scale_requests, seed)
    results["forecast_replan"] = _measure_forecast_replan(seed + 14)
    results["ktuner"] = _measure_ktuner(seed + 6)
    results["edf"] = _measure_edf()

    if csv:
        sc = results["scale"]
        print(f"sim:scale.replay_req_per_s,{sc['replay_req_per_s']:.0f},"
              f"{sc['requests']}")
        print(f"sim:scale.scale_ok,{sc['scale_ok']:.0f},"
              f"floor={SCALE_RATE_FLOOR:.0f}")
        print(f"sim:scale.determinism_ok,{sc['determinism_ok']:.0f},"
              f"{sc['determinism_events']}")
        fr = results["forecast_replan"]
        for mode in ("ewma", "forecast"):
            r = fr[mode]
            print(f"sim:forecast_replan.{mode}.p50/p99_ms,"
                  f"{r['p50_ms']:.1f},{r['p99_ms']:.1f}")
        print(f"sim:forecast_replan.p99_improvement,"
              f"{fr['p99_improvement']:.2f},")
        kt = results["ktuner"]
        print(f"sim:ktuner.recommended,{kt['recommended']},"
              f"ok={kt['recommended_ok']:.0f}")
        ed = results["edf"]
        print(f"sim:edf.misses_fifo/edf,{ed['fifo']['deadline_misses']},"
              f"{ed['edf']['deadline_misses']}")
        print(f"sim:edf.miss_reduction,{ed['miss_reduction']:.2f},")
    return results


if __name__ == "__main__":
    run()
