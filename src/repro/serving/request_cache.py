"""Request-level prediction cache (paper §I.B: "to improve performance under
redundant requests, caching allows avoiding recomputing similar requests").

Keyed by the content hash of each sample row; LRU-bounded.  Integrated by
the EnsembleClient facade and the HTTP layer: cached rows are answered
immediately, only the misses travel through the inference system, and the
merged result preserves row order.

A prediction is only reusable under the same ensemble configuration, so
callers passing per-request options must ``salt`` the key with their
(members, combine) fingerprint — a member-subset request must never be
answered with a full-ensemble entry (the facade does this automatically).
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np


def row_key(row: np.ndarray) -> bytes:
    return hashlib.blake2b(row.tobytes(), digest_size=16).digest() + \
        str(row.shape).encode()


class PredictionCache:
    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._store: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, X: np.ndarray, salt: bytes = b"") -> \
            Tuple[List[Optional[np.ndarray]], List[int]]:
        """Returns (per-row cached predictions or None, indices of misses)."""
        out: List[Optional[np.ndarray]] = []
        misses: List[int] = []
        with self._lock:
            for i, row in enumerate(X):
                k = row_key(row) + salt
                hit = self._store.get(k)
                if hit is not None:
                    self._store.move_to_end(k)
                    self.hits += 1
                    out.append(hit)
                else:
                    self.misses += 1
                    out.append(None)
                    misses.append(i)
        return out, misses

    def insert(self, X: np.ndarray, Y: np.ndarray, salt: bytes = b"") -> None:
        with self._lock:
            for row, y in zip(X, Y):
                k = row_key(row) + salt
                self._store[k] = np.asarray(y)
                self._store.move_to_end(k)
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def predict_through(self, system, X: np.ndarray) -> np.ndarray:
        """Serve X via the cache: only misses hit the inference system.

        Degraded results never enter the unsalted (full-quality) key space:
        a brownout-tier combine answered here would otherwise be replayed
        as a full-ensemble answer long after pressure subsides
        (DESIGN.md §11)."""
        cached, miss_idx = self.lookup(X)
        if miss_idx:
            missing = X[miss_idx]
            submit = getattr(system, "predict_async", None)
            if submit is not None:
                h = submit(missing)
                Y_miss = h.result(600.0)
                quality = float(getattr(h, "quality", 1.0))
            else:                       # bare predict-only backends: assume
                Y_miss = system.predict(missing)   # full quality
                quality = 1.0
            if quality >= 1.0:
                self.insert(missing, Y_miss)
            for j, i in enumerate(miss_idx):
                cached[i] = Y_miss[j]
        return np.stack(cached, axis=0)
