"""Architecture registry: ``get_config("qwen3-1.7b")`` and friends."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ATTN, CROSS, HYBRID, SSM, SWA, ModelConfig,
                                MoEConfig, SSMConfig)

from repro.configs.qwen3_1p7b import CONFIG as _qwen3
from repro.configs.h2o_danube_1p8b import CONFIG as _danube
from repro.configs.llama32_vision_11b import CONFIG as _llama32v
from repro.configs.granite_moe_3b import CONFIG as _granite
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.gemma3_1b import CONFIG as _gemma3
from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.llama4_scout import CONFIG as _llama4
from repro.configs.mamba2_1p3b import CONFIG as _mamba2
from repro.configs.musicgen_large import CONFIG as _musicgen

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.name: c for c in [
        _qwen3, _danube, _llama32v, _granite, _llama3,
        _gemma3, _hymba, _llama4, _mamba2, _musicgen,
    ]
}

# Input shapes assigned to this paper (see system brief).
INPUT_SHAPES = {
    "train_4k":    dict(seq_len=4096,    global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,   global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,   global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288,  global_batch=1,   kind="decode"),
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown architecture {name!r}; have {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def list_architectures() -> List[str]:
    return sorted(ARCHITECTURES)


def long_context_ok(cfg: ModelConfig) -> bool:
    """Whether the long_500k decode shape applies.

    SSM / hybrid / sliding-window stacks qualify outright; mixed local:global
    stacks (gemma3's 5:1) qualify when unbounded-attention layers are a small
    minority (<=20%) — their KV caches are seq-sharded over the mesh "data"
    axis while the windowed majority stays O(window).  Pure full-attention
    archs are skipped (see DESIGN.md §4)."""
    if cfg.sub_quadratic:
        return True
    kinds = cfg.layer_kinds()
    unbounded = sum(k in (ATTN, CROSS) for k in kinds)
    bounded = sum(k in (SWA, SSM, HYBRID) for k in kinds)
    return bounded > 0 and unbounded / len(kinds) <= 0.2


# ---------------------------------------------------------------------------
# Paper-style ensembles, rebuilt from the assigned architecture pool.
# The paper's IMN1/IMN4/IMN12 are ensembles of 1/4/12 heterogeneous CNNs;
# we mirror the sizes with heterogeneous *reduced* LM variants so the
# allocation problem keeps the paper's shape (heterogeneous memory/latency).
# ENS* members are (config, instance-suffix) -- an arch may appear twice with
# different reductions, like the paper's ResNet50 vs ResNet101.
# ---------------------------------------------------------------------------
def ensemble(name: str) -> List[ModelConfig]:
    import dataclasses
    reds = {k: v.reduced() for k, v in ARCHITECTURES.items()}

    def resize(cfg: ModelConfig, layers: int, d_model: int, tag: str) -> ModelConfig:
        unit = len(cfg.pattern)
        layers = max(unit, (layers // unit) * unit)
        base = ARCHITECTURES[cfg.name.replace("-reduced", "")]
        out = base.reduced(layers=layers, d_model=d_model)
        return dataclasses.replace(out, name=f"{base.name}-{tag}")

    if name == "ENS1":        # paper IMN1: one single heavy DNN
        return [resize(_llama3, 4, 384, "ens1")]
    if name == "ENS4":        # paper IMN4: 4 heterogeneous models
        return [
            resize(_qwen3, 2, 256, "s"),
            resize(_llama3, 4, 384, "m"),
            resize(_gemma3, 13, 256, "s"),
            resize(_granite, 2, 256, "moe"),
        ]
    if name == "ENS12":       # paper IMN12: 12 heterogeneous models
        out = []
        # every member a distinct (layers, width) like the paper's mix of
        # ResNet18..152 / VGG / Inception — no two identical latency profiles
        sizes = [(2, 192), (2, 224), (2, 256), (2, 288), (4, 224), (4, 256),
                 (4, 288), (4, 320), (4, 384), (6, 256), (6, 320), (8, 384)]
        archs = [_qwen3, _danube, _llama3, _gemma3, _granite, _hymba,
                 _mamba2, _musicgen, _llama4, _llama32v, _qwen3, _llama3]
        for i, a in enumerate(archs):
            L, D = sizes[i]
            out.append(resize(a, L, D, f"e{i}"))
        return out
    raise KeyError(f"unknown ensemble {name!r} (have ENS1, ENS4, ENS12)")
