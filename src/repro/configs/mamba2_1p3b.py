"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality), no MLP blocks.
[arXiv:2405.21060]"""
from repro.configs.base import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                        # pure mixer stack, no MLP
    vocab_size=50280,
    pattern=(SSM,),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=64),
    tie_embeddings=True,
    vocab_pad_to=2048,             # 50280 -> 51200
    source="arXiv:2405.21060",
)
