"""The Best-Batch-Size (BBS) baseline (paper §I.A, Table III).

One model per accelerator (requires as many accelerators as models — the
paper calls out this rigidity).  Each model's batch size is scanned
*independently* with a single-model benchmark, exactly like the
model-analyzer-style tools the paper cites.  ``#bench == M * |batch_sizes|``
(IMN4 on 4 GPUs -> 20, matching Table III).
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.allocation import (DEFAULT_BATCH_SIZES, AllocationMatrix,
                                   zeros)
from repro.core.devices import DeviceSpec

# (cfg, device, batch) -> samples/sec of that model alone on that device
SingleBench = Callable[[ModelConfig, DeviceSpec, int], float]


class BBSError(RuntimeError):
    pass


def analytic_single_bench(seq: int = 128, dtype_bytes: int = 4,
                          overhead_s: float = 2e-4) -> SingleBench:
    """Single-model roofline bench consistent with core.bench.AnalyticBench
    (returns 0 when the worker doesn't fit the device, like the paper's
    bench on an OOM)."""
    from repro.core.bench import AnalyticBench
    from repro.core.memory import worker_bytes

    def fn(cfg: ModelConfig, dev: DeviceSpec, batch: int) -> float:
        if worker_bytes(cfg, batch, seq, dtype_bytes) > dev.memory_bytes:
            return 0.0
        ab = AnalyticBench([cfg], seq=seq, dtype_bytes=dtype_bytes,
                           overhead_s=overhead_s)
        return batch / ab.worker_time(dev, cfg, batch)
    return fn


def measured_single_bench(params_for: Callable[[ModelConfig], object],
                          calib_x, segment_size: int = 128) -> SingleBench:
    """Single-model measured bench (builds a 1-model inference system)."""
    def fn(cfg: ModelConfig, dev: DeviceSpec, batch: int) -> float:
        from repro.core.allocation import AllocationMatrix
        from repro.serving.system import InferenceSystem
        import numpy as np
        alloc = AllocationMatrix([dev], [cfg.name], np.array([[batch]]))
        system = InferenceSystem([cfg], [params_for(cfg)], alloc,
                                 segment_size=segment_size)
        try:
            _, thr = system.benchmark(calib_x)
        finally:
            system.shutdown()
        return thr
    return fn


def best_batch_strategy(cfgs: Sequence[ModelConfig],
                        devices: List[DeviceSpec],
                        bench_single: SingleBench, *,
                        batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES
                        ) -> Tuple[AllocationMatrix, int]:
    """Returns (allocation, number of bench calls)."""
    accels = [d for d, dev in enumerate(devices) if dev.is_accelerator]
    if len(accels) < len(cfgs):
        raise BBSError(
            f"BBS needs >= {len(cfgs)} accelerators, got {len(accels)} "
            "(the baseline's rigidity — see paper §IV.C)")
    names = [c.name for c in cfgs]
    final = zeros(devices, names)
    nbench = 0
    for m, cfg in enumerate(cfgs):
        d = accels[m]
        best_b, best_s = batch_sizes[0], -1.0
        for b in batch_sizes:
            s = bench_single(cfg, devices[d], b)
            nbench += 1
            if s > best_s:
                best_b, best_s = b, s
        final.A[d, m] = best_b
    final.validate()
    return final, nbench
