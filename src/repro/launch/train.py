"""Pod-scale training launcher.

On real TPU hardware this runs the sharded train loop on the production
mesh; on this CPU container use ``--host-demo`` for a real (small-mesh)
run or ``--dry-run`` to lower/compile only.

    python -m repro.launch.train --arch llama3-8b --shape train_4k --dry-run
    python -m repro.launch.train --arch qwen3-1.7b --host-demo --steps 20
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--host-demo", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch.dryrun import run_one
        rec = run_one(args.arch, args.shape,
                      "multi" if args.multi_pod else "single")
        return 0 if rec["ok"] else 1

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    import repro.models as M
    from repro.data.pipeline import SyntheticLM, shard_batch
    from repro.launch.mesh import make_host_mesh, make_production_mesh, batch_axes
    from repro.parallel import sharding as shd
    from repro.training import checkpoint as ckpt
    from repro.training import optimizer as opt
    from repro.training.train_loop import make_train_step

    if args.host_demo:
        cfg = get_config(args.arch).reduced()
        mesh = make_host_mesh(1, 1)
        batch_size, seq = 8, 64
    else:   # real pod
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        from repro.configs import INPUT_SHAPES
        sh = INPUT_SHAPES[args.shape]
        batch_size, seq = sh["global_batch"], sh["seq_len"]

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    ocfg = opt.AdamWConfig(total_steps=args.steps)
    pshard = shd.param_shardings(cfg, mesh)
    params = jax.device_put(params, pshard)
    state = jax.device_put(state, opt.AdamWState(
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        pshard, pshard))
    step_fn = jax.jit(make_train_step(cfg, ocfg, remat=True))
    gen = SyntheticLM(cfg.vocab_size, seq, task="ngram")
    it = gen.iterator(batch_size, cfg)

    with mesh:
        for i in range(args.steps):
            batch = shard_batch(next(it), mesh, batch_axes(mesh) or ("data",))
            t0 = time.perf_counter()
            params, state, metrics = step_fn(params, state, batch)
            loss = float(metrics["loss"])
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({time.perf_counter() - t0:.2f}s)", flush=True)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, jax.device_get(params))
        print("checkpoint saved to", args.ckpt_dir)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
