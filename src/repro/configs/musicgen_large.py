"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens
(vocab 2048); the EnCodec encoder/mel frontend is the sanctioned stub.
[arXiv:2306.05284]"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,               # MHA (GQA kv=32)
    d_ff=8192,
    vocab_size=2048,
    pattern=(ATTN,),
    rope_theta=10000.0,            # source uses sinusoidal; RoPE noted in DESIGN
    source="arXiv:2306.05284",
)
