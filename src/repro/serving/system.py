"""The inference system core (paper §II.C): ``f(X, A) -> {Y, S}``.

"Deploy Mode": ``predict(X) -> Y`` serves requests.
"Benchmark Mode": ``benchmark(X) -> (Y, S)`` measures the throughput S of
allocation matrix A on calibration samples.

Processes (threads here — DESIGN.md §2): the *segment ids broadcaster*, the
*worker pool* and the *prediction accumulator*, wired by thread-safe FIFO
queues; sample bytes live in the shared X buffer, only integer segment ids
travel through queues.
"""
from __future__ import annotations

import queue
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocation import AllocationMatrix
from repro.serving import segments as seg
from repro.serving.accumulator import PredictionAccumulator
from repro.serving.segments import DEFAULT_SEGMENT_SIZE, SHUTDOWN, Message
from repro.serving.worker import Worker


class InferenceSystem:
    def __init__(self, cfgs: Sequence[ModelConfig], params_list,
                 alloc: AllocationMatrix, *,
                 segment_size: int = DEFAULT_SEGMENT_SIZE,
                 combine: str = "mean",
                 weights: Optional[np.ndarray] = None,
                 fake: bool = False,
                 frontends: Optional[Dict[int, np.ndarray]] = None,
                 max_seq: int = 128,
                 use_kernel: bool = False,
                 ready_timeout: float = 300.0):
        alloc.validate()
        self.cfgs = list(cfgs)
        self.alloc = alloc
        self.segment_size = segment_size
        self.M = len(self.cfgs)
        classes = {c.vocab_size for c in self.cfgs}
        if len(classes) != 1:
            raise ValueError(f"ensemble members disagree on class count: {classes}")
        self.num_classes = classes.pop()

        # shared memory X buffer (paper: the heavy bytes live here, readable
        # by every worker; queues carry only segment ids)
        self.shared_x = np.zeros((segment_size, max_seq), np.int32)

        self.prediction_queue: "queue.Queue[Message]" = queue.Queue()
        self.model_queues: List[queue.Queue] = [queue.Queue() for _ in self.cfgs]
        self.accumulator = PredictionAccumulator(
            self.prediction_queue, self.M, combine=combine, weights=weights)

        self.workers: List[Worker] = []
        frontends = frontends or {}
        for d, m, batch in alloc.workers():
            w = Worker(f"w{d}.{m}", self.cfgs[m], params_list[m],
                       alloc.devices[d], batch,
                       self.model_queues[m], self.prediction_queue, m,
                       self.shared_x, segment_size, fake=fake,
                       frontend=frontends.get(m), use_kernel=use_kernel)
            self.workers.append(w)

        self.accumulator.expect_ready(len(self.workers))
        self.accumulator.start()
        for w in self.workers:
            w.start()
        if not self.accumulator.all_ready.wait(ready_timeout):
            raise TimeoutError("workers failed to initialize")
        self._shutdown = False

    # ---- the segment ids broadcaster -----------------------------------------
    def _broadcast(self, X: np.ndarray, members=None):
        n = X.shape[0]
        if X.shape[0] > self.shared_x.shape[0] or X.shape[1] != self.shared_x.shape[1]:
            self.shared_x = np.zeros((max(n, self.shared_x.shape[0]), X.shape[1]),
                                     np.int32)
            for w in self.workers:
                w.shared_x = self.shared_x
        self.shared_x[:n] = X
        members = list(range(self.M)) if members is None else list(members)
        self.accumulator.begin(n, self.num_classes, self.segment_size, members)
        for s in range(seg.num_segments(n, self.segment_size)):
            for m in members:
                self.model_queues[m].put((s, n))

    # ---- modes -----------------------------------------------------------------
    def predict(self, X: np.ndarray, timeout: float = 600.0,
                members=None) -> np.ndarray:
        """Deploy Mode.  ``members``: optional model-id subset (paper §I.B
        "ensemble selection" — e.g. a faster accuracy/speed trade-off)."""
        self._broadcast(np.asarray(X, np.int32), members)
        Y = self.accumulator.wait(timeout)
        if self.accumulator.oom.is_set():
            self.shutdown()
            raise MemoryError("a worker reported OOM ({-1, None, None})")
        return Y

    def benchmark(self, X: np.ndarray, repeats: int = 1,
                  timeout: float = 600.0):
        """Benchmark Mode: returns (Y, throughput samples/sec)."""
        X = np.asarray(X, np.int32)
        Y = self.predict(X, timeout)          # warm the path once
        t0 = time.perf_counter()
        for _ in range(repeats):
            self._broadcast(X)
            Y = self.accumulator.wait(timeout)
        dt = time.perf_counter() - t0
        return Y, repeats * X.shape[0] / dt

    def shutdown(self):
        if self._shutdown:
            return
        self._shutdown = True
        for m, q in enumerate(self.model_queues):
            for _ in [w for w in self.workers if w.model_idx == m]:
                q.put(SHUTDOWN)
        for w in self.workers:
            w.join()
        self.accumulator.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
