"""Pallas kernel validation (deliverable c): shape/dtype sweeps, allclose vs
the pure-jnp oracles in kernels/ref.py, in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


FLASH_CASES = [
    # (b, s, h, kv, hd, window, dtype)
    (2, 64, 4, 2, 32, 0, jnp.float32),
    (1, 128, 4, 4, 64, 0, jnp.float32),
    (2, 96, 8, 2, 80, 32, jnp.float32),    # non-128 head_dim (danube-like)
    (1, 256, 4, 1, 128, 64, jnp.float32),  # MQA + window (gemma-like)
    (1, 200, 2, 2, 48, 0, jnp.float32),    # ragged seq (padding path)
    (2, 64, 4, 2, 64, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,kv,hd,window,dtype", FLASH_CASES)
def test_flash_attention(b, s, h, kv, hd, window, dtype):
    q = _rand(1, b, s, h, hd, dtype=dtype)
    k = _rand(2, b, s, kv, hd, dtype=dtype)
    v = _rand(3, b, s, kv, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 64, 4, 2, 32, jnp.float32),
    (1, 300, 8, 2, 80, jnp.float32),       # unpadded cache length
    (3, 1024, 4, 1, 128, jnp.float32),
    (2, 128, 4, 4, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("b,L,h,kv,hd,dtype", DECODE_CASES)
def test_decode_attention(b, L, h, kv, hd, dtype):
    q = _rand(4, b, 1, h, hd, dtype=dtype)
    k = _rand(5, b, L, kv, hd, dtype=dtype)
    v = _rand(6, b, L, kv, hd, dtype=dtype)
    valid = jnp.arange(L) < (L - 7)
    out = ops.decode_attention(q, k, v, valid)
    exp = ref.decode_attention_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


SSD_CASES = [
    (2, 64, 4, 32, 16, 16),
    (1, 128, 8, 64, 32, 32),
    (2, 100, 4, 32, 16, 16),               # padded seq
    (1, 64, 2, 64, 128, 64),               # full mamba2-like state
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_CASES)
def test_ssd_scan(b, s, h, p, n, chunk):
    x = _rand(7, b, s, h, p)
    dt = jax.nn.softplus(_rand(8, b, s, h))
    A = -jnp.exp(_rand(9, h) * 0.5)
    bm, cm = _rand(10, b, s, n), _rand(11, b, s, n)
    out = ops.ssd_scan(x, dt, A, bm, cm, chunk=chunk)
    exp = ref.ssd_scan_sequential_ref(x, dt, A, bm, cm)
    scale = float(jnp.abs(exp).max())
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4 * max(scale, 1), rtol=1e-4)
    # the chunked jnp reference agrees too (kernel oracle = model impl)
    exp2 = ref.ssd_scan_ref(x, dt, A, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(exp2), np.asarray(exp),
                               atol=1e-4 * max(scale, 1), rtol=1e-4)


COMBINE_CASES = [(4, 128, 100), (12, 44, 91), (3, 128, 1000), (1, 7, 13)]


@pytest.mark.parametrize("m,seg,c", COMBINE_CASES)
def test_ensemble_combine(m, seg, c):
    p = _rand(12, m, seg, c)
    w = jax.nn.softmax(_rand(13, m))
    out = ops.ensemble_combine(p, w)
    exp = ref.ensemble_combine_ref(p, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_ensemble_combine_is_paper_rule():
    """Uniform weights reproduce Y += P/M exactly."""
    m, seg, c = 5, 16, 10
    p = _rand(14, m, seg, c)
    w = jnp.full((m,), 1.0 / m)
    out = ops.ensemble_combine(p, w)
    acc = np.zeros((seg, c), np.float32)
    for i in range(m):
        acc += np.asarray(p[i]) / m
    np.testing.assert_allclose(np.asarray(out), acc, atol=1e-6)


@pytest.mark.parametrize("m,seg,c", COMBINE_CASES)
def test_ensemble_accumulate(m, seg, c):
    """The accumulate-into-partial kernel variant == partial + weighted sum."""
    p = _rand(15, m, seg, c)
    w = jax.nn.softmax(_rand(16, m))
    part = _rand(17, seg, c)
    out = ops.ensemble_accumulate(part, p, w)
    exp = ref.ensemble_accumulate_ref(part, p, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_ensemble_accumulate_chains():
    """Folding members one at a time (the device combiner's usage) equals a
    single fused combine."""
    m, seg, c = 4, 20, 100                 # non-block-aligned on purpose
    p = _rand(18, m, seg, c)
    w = jax.nn.softmax(_rand(19, m))
    acc = jnp.zeros((seg, c), jnp.float32)
    for i in range(m):
        acc = ops.ensemble_accumulate(acc, p[i][None], w[i][None])
    exp = ref.ensemble_combine_ref(p, w)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(exp), atol=1e-5)


def test_kernels_used_by_models_match():
    """flash_attention kernel path == model jnp path inside self-attention."""
    from repro.configs import get_config
    import repro.models as M
    cfg = get_config("llama3-8b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l1, _ = M.forward(params, cfg, tokens, use_kernel=False)
    l2, _ = M.forward(params, cfg, tokens, use_kernel=True)
    assert float(jnp.abs(l1 - l2).max()) < 2e-3
