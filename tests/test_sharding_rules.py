"""Sharding-rule invariants, for every architecture x variant, on abstract
production meshes (no devices needed): every sharded dim divides its mesh
axes, specs match tree structure, and variant behaviors hold."""
import numpy as np
import pytest

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import runtime_flags
from repro.configs import INPUT_SHAPES, list_architectures, get_config
from repro.models.transformer import param_shapes
from repro.parallel import sharding as shd

def _abstract_mesh(sizes, names):
    """jax moved AbstractMesh to a ((name, size), ...) shape tuple in 0.4.37;
    accept both call conventions."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except (TypeError, ValueError):
        return AbstractMesh(sizes, names)


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, entry) -> int:
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _check_tree(shapes, specs, mesh):
    flat_shapes = jax.tree.leaves(shapes, is_leaf=lambda x: isinstance(x, tuple))
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for shape, spec in zip(flat_shapes, flat_specs):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            assert shape[dim] % _axis_size(mesh, entry) == 0, (shape, spec)


@pytest.fixture(autouse=True)
def _reset_variant():
    yield
    runtime_flags.set_variant("baseline")


@pytest.mark.parametrize("arch", list_architectures())
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    for variant in ("baseline", "attn_repl", "fsdp", "attn_repl+fsdp"):
        runtime_flags.set_variant(variant, mesh)
        specs = shd.param_specs(cfg, shapes, mesh)
        _check_tree(shapes, specs, mesh)


@pytest.mark.parametrize("arch", list_architectures())
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    from repro.models.cache import layer_cache_struct
    for shape_name in ("decode_32k", "long_500k"):
        sh = INPUT_SHAPES[shape_name]
        b, s = sh["global_batch"], sh["seq_len"]
        for variant in ("baseline", "cache_seqshard", "attn_repl", "kv_int8"):
            runtime_flags.set_variant(variant, MESH1)
            specs = shd.cache_specs(cfg, MESH1, b, s)
            for kind, entry in zip(cfg.pattern, specs["layers"]):
                struct = layer_cache_struct(
                    cfg, kind, b, s,
                    quantized=bool(runtime_flags.SHARDING_OPTS.get("kv_quant")))
                for name, spec in entry.items():
                    shape = (cfg.repeats,) + struct[name][0]
                    for dim, ax in enumerate(spec):
                        if ax is None:
                            continue
                        assert shape[dim] % _axis_size(MESH1, ax) == 0, \
                            (arch, shape_name, variant, name, shape, spec)


def test_attn_repl_replicates_small_heads():
    cfg = get_config("gemma3-1b")          # 4 q / 1 kv heads, indivisible
    shapes = param_shapes(cfg)
    runtime_flags.set_variant("attn_repl", MESH1)
    specs = shd.param_specs(cfg, shapes, MESH1)
    unit = specs["layers"][0]
    assert unit["wq"] == P(None, None, None, None)
    assert unit["wk"] == P(None, None, None, None)
    runtime_flags.set_variant("baseline")
    specs_b = shd.param_specs(cfg, shapes, MESH1)
    assert specs_b["layers"][0]["wq"] == P(None, None, None, "model")  # hd fallback


def test_fsdp_adds_data_axis():
    cfg = get_config("llama4-scout-17b-a16e")
    shapes = param_shapes(cfg)
    runtime_flags.set_variant("fsdp", MESH1)
    specs = shd.param_specs(cfg, shapes, MESH1)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    frac_data = sum("data" in [a for e in spec if e is not None
                               for a in ((e,) if isinstance(e, str) else e)]
                    for spec in flat) / len(flat)
    assert frac_data > 0.5     # most tensors gain a data-sharded dim


def test_batch_spec_long_context_falls_back_to_seq():
    spec = shd.batch_spec(MESH1, 1, 2, seq_dim=1, seq_len=524288)
    assert spec == P(None, "data")
    spec2 = shd.batch_spec(MESH1, 256, 2)
    assert spec2 == P("data", None)
    spec3 = shd.batch_spec(MESH2, 256, 2)
    assert spec3 == P(("pod", "data"), None)
