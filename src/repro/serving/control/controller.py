"""The online reconfiguration controller (DESIGN.md §8).

A background thread with two cadences:

* a **fast loop** (``steal_interval_s``, default 2 ms) runs the
  work-stealing balancer over every member with >= 2 data-parallel
  instances;
* a **slow loop** (``interval_s``, default 2 s) re-runs the paper's
  Algorithm 2 (bounded greedy) from the *current live allocation* against
  the :class:`~repro.serving.control.livebench.LiveBench` profile, and
  applies the winning matrix's delta as live actions.

Delta application is ordered so the ensemble stays fully served and no
in-flight request is dropped: **spawns** first (capacity only goes up),
then **rebatches** (spawn the new-batch instance, then drain the old one —
a generation-tagged replacement; both serve during the handover), then
**drains** (the retiring worker leaves routing atomically, its queued
descriptors migrate to siblings, and the SHUTDOWN sentinel lets work
already accepted finish).  A failed spawn rejects that one action — the
probe worker posts no OOM sentinel, so in-flight requests never pay for a
speculative reconfiguration.

Every action appends to a bounded event log exported via ``stats()`` (the
HTTP server's ``/metrics`` and ``EnsembleClient.metrics()`` surface it).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.allocation import DEFAULT_BATCH_SIZES, AllocationMatrix
from repro.core.greedy import bounded_greedy
from repro.serving.control import stealing
from repro.serving.control.livebench import LiveBench


class ReconfigController:
    def __init__(self, system, *, live: Optional[LiveBench] = None,
                 interval_s: float = 2.0, steal_interval_s: float = 0.002,
                 steal_threshold: int = 4, steal_max: int = 32,
                 min_gain: float = 1.15, min_observations: int = 32,
                 batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
                 max_iter: int = 3, max_neighs: int = 24,
                 replan: bool = True, steal: bool = True, seed: int = 0,
                 spawn_backoff_s: float = 0.5,
                 spawn_backoff_cap_s: float = 30.0):
        self.system = system
        self.live = live or LiveBench(system.cfgs, seq=system.max_seq)
        self.interval_s = interval_s
        self.steal_interval_s = steal_interval_s
        self.steal_threshold = steal_threshold
        self.steal_max = steal_max
        self.min_gain = min_gain
        self.min_observations = min_observations
        self.batch_sizes = tuple(batch_sizes)
        self.max_iter = max_iter
        self.max_neighs = max_neighs
        self.replan_enabled = replan
        self.steal_enabled = steal
        self.seed = seed
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stats_lock = threading.Lock()
        self.counters = {k: 0 for k in
                         ("replans", "applied", "spawns", "drains",
                          "rebatches", "steals", "stolen",
                          "spawn_failures", "respawns")}
        self.events: "deque[dict]" = deque(maxlen=64)
        # per-(device, member) spawn backoff: a failed spawn (device can't
        # host it) is skipped silently until its deadline instead of being
        # re-proposed — and re-failing — every replan tick (DESIGN.md §10)
        self.spawn_backoff_s = spawn_backoff_s
        self.spawn_backoff_cap_s = spawn_backoff_cap_s
        self._backoff: dict = {}          # (d, m) -> [fails, retry_at]
        # members whose last instance was quarantined: (d, batch) to respawn
        # in the background (Supervisor -> note_member_down)
        self._respawns: dict = {}         # m -> (d, batch)
        system.set_profiler(self.live)    # workers + broadcaster feed it
        system.controller = self

    # ---- lifecycle -----------------------------------------------------------
    def start(self) -> "ReconfigController":
        self._stop.clear()                # stop()/start() cycles are legal
        self._thread = threading.Thread(target=self._run, name="reconfig",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def _run(self) -> None:
        # replan-only mode has no reason to spin at the stealer's cadence
        tick = self.steal_interval_s if self.steal_enabled \
            else self.interval_s
        next_replan = time.perf_counter() + self.interval_s
        while not self._stop.wait(tick):
            try:
                if self._respawns:        # member down: recovery first
                    self.respawn_once()
                if self.steal_enabled:
                    self.steal_once()
                if self.replan_enabled and \
                        time.perf_counter() >= next_replan:
                    self.replan_once()
                    next_replan = time.perf_counter() + self.interval_s
            except Exception as e:        # the control plane must outlive
                self._event("error", f"{type(e).__name__}: {e}")

    # ---- the fast path: work stealing ----------------------------------------
    def steal_once(self) -> int:
        """One balancing sweep over every member."""
        moved = 0
        for m in range(self.system.M):
            moved += stealing.balance_member(
                self.system, m, threshold=self.steal_threshold,
                max_items=self.steal_max, profile=self.live)
        if moved:
            with self._stats_lock:
                self.counters["steals"] += 1
                self.counters["stolen"] += moved
        return moved

    # ---- member respawn (fault tolerance, DESIGN.md §10) ---------------------
    def note_member_down(self, m: int, d: int, batch: int) -> None:
        """Called by the supervisor when member ``m`` lost its LAST instance
        (it was on device ``d`` at ``batch``).  Records the respawn intent;
        the controller loop retries it in the background under the spawn
        backoff until an instance lands."""
        with self._stats_lock:
            self._respawns[m] = (d, batch)
        self._event("member_down", f"m{m}: last instance (d{d} b{batch}) "
                                   f"quarantined; respawning in background")

    def respawn_once(self) -> int:
        """Attempt every pending member respawn (backoff-gated).  Returns
        the number of members brought back."""
        with self._stats_lock:
            pending = dict(self._respawns)
        back = 0
        for m, (d, b) in pending.items():
            if self.system.instances(m):  # raced a concurrent recovery
                with self._stats_lock:
                    self._respawns.pop(m, None)
                continue
            if self._spawn(d, m, b, self.system.generation):
                with self._stats_lock:
                    self._respawns.pop(m, None)
                    self.counters["respawns"] += 1
                self._event("respawned", f"member {m} back on d{d} b{b}")
                back += 1
        return back

    # ---- the slow path: live replanning --------------------------------------
    def replan_once(self) -> bool:
        """Score the live allocation, search its neighborhood against the
        live profile, and apply the delta when the projected gain clears
        ``min_gain``.  Returns whether a reconfiguration was applied."""
        if self.live.observations < self.min_observations:
            return False                  # profile too cold to trust
        with self.system._submit_lock:
            current = self.system.alloc.copy()
        with self._stats_lock:
            self.counters["replans"] += 1
        cur_score = self.live(current)
        if cur_score <= 0.0:
            return False
        proposed, _trace = bounded_greedy(
            current, self.live, max_iter=self.max_iter,
            max_neighs=self.max_neighs, batch_sizes=self.batch_sizes,
            seed=self.seed)
        if np.array_equal(proposed.A, current.A):
            return False
        prop_score = self.live(proposed)
        if prop_score < cur_score * self.min_gain:
            self._event("replan_held",
                        f"gain {prop_score / cur_score:.2f}x < "
                        f"{self.min_gain:.2f}x threshold")
            return False
        self.apply(proposed, current=current)
        return True

    def apply(self, target: AllocationMatrix, *,
              current: Optional[AllocationMatrix] = None) -> None:
        """Apply ``current -> target`` as live actions under a new
        generation.  Actions are individually atomic; a failed spawn rejects
        its action (and the paired drain) without touching the rest."""
        sys_ = self.system
        if current is None:
            with sys_._submit_lock:
                current = sys_.alloc.copy()
        sys_.generation += 1
        gen = sys_.generation
        spawns, rebatches, drains = [], [], []
        D, M = current.A.shape
        for d in range(D):
            for m in range(M):
                old, new = int(current.A[d, m]), int(target.A[d, m])
                if old == new:
                    continue
                if old == 0:
                    spawns.append((d, m, new))
                elif new == 0:
                    drains.append((d, m))
                else:
                    rebatches.append((d, m, new))
        done = {"spawn": 0, "rebatch": 0, "drain": 0}
        for d, m, b in spawns:
            if self._spawn(d, m, b, gen):
                done["spawn"] += 1
        for d, m, b in rebatches:
            old_w = self._find(d, m, before_gen=gen)
            if old_w is None or not self._spawn(d, m, b, gen):
                continue
            self._drain(old_w)            # replacement landed; retire old
            done["rebatch"] += 1
        for d, m in drains:
            w = self._find(d, m, before_gen=gen)
            if w is not None and self._drain(w):
                done["drain"] += 1
        with self._stats_lock:
            self.counters["spawns"] += done["spawn"]
            self.counters["rebatches"] += done["rebatch"]
            self.counters["drains"] += done["drain"]
            if any(done.values()):        # counters/events report what
                self.counters["applied"] += 1      # actually happened
        if any(done.values()):
            self._event("applied", f"generation {gen}: "
                        f"{done['spawn']} spawn / {done['rebatch']} rebatch "
                        f"/ {done['drain']} drain -> "
                        f"A={sys_.alloc.A.tolist()}")
        else:
            self._event("apply_noop",
                        f"generation {gen}: every action failed "
                        f"({len(spawns)} spawn / {len(rebatches)} rebatch / "
                        f"{len(drains)} drain attempted)")

    # ---- action helpers ------------------------------------------------------
    def _find(self, d: int, m: int, *, before_gen: int):
        for w in self.system.instances(m):
            if w.device_idx == d and w.generation < before_gen:
                return w
        return None

    def _spawn(self, d: int, m: int, b: int, gen: int) -> bool:
        key = (d, m)
        now = time.perf_counter()
        state = self._backoff.get(key)
        if state is not None and now < state[1]:
            return False                  # still backing off; skip silently
        try:
            self.system.spawn_instance(d, m, b, generation=gen)
            self._backoff.pop(key, None)  # success clears the backoff
            return True
        except Exception as e:            # reject ONE action, keep serving
            fails = (state[0] if state else 0) + 1
            delay = min(self.spawn_backoff_cap_s,
                        self.spawn_backoff_s * 2 ** (fails - 1))
            self._backoff[key] = [fails, now + delay]
            with self._stats_lock:
                self.counters["spawn_failures"] += 1
            self._event("spawn_failed",
                        f"d{d} m{m} b{b}: {e} (attempt {fails}, "
                        f"next retry in {delay:.1f}s)")
            return False

    def _drain(self, w) -> bool:
        try:
            self.system.drain_instance(w, wait=False)
            return True
        except ValueError as e:           # sole instance: keep it
            self._event("drain_skipped", str(e))
            return False

    def _event(self, kind: str, detail: str) -> None:
        with self._stats_lock:
            self.events.append({"t": time.time(), "kind": kind,
                                "detail": detail})

    # ---- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Controller counters + live-profile snapshot for ``/metrics``."""
        with self._stats_lock:
            counters = dict(self.counters)
            events = list(self.events)[-8:]
        with self.system._submit_lock:
            workers = [{"id": w.worker_id, "device": w.device_idx,
                        "model": w.model_idx, "batch": w.batch_size,
                        "generation": w.generation,
                        "queue_depth": w.input_queue.qsize()}
                       for w in self.system.workers]
        return {"generation": self.system.generation,
                "enabled": {"replan": self.replan_enabled,
                            "steal": self.steal_enabled},
                "counters": counters,
                "workers": workers,
                "live": self.live.snapshot(),
                "events": events}
