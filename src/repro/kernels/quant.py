"""Shared quantization helpers (per-channel symmetric int8 / fp8).

Single home for the reduced-precision math used across the stack:

  * the int8 KV decode cache (``models/cache.py`` re-exports
    :func:`quantize_kv` / :func:`dequantize_kv` from here),
  * quantized member execution in the serving worker (weight-only
    per-output-channel param quantization + per-row logit quantization
    feeding the fused dequant-weight-accumulate combine epilogue in
    ``kernels/ensemble_combine.py``),
  * the allocator's dtype-size-aware memory footprints
    (:func:`dtype_bytes`).

Symmetric scheme throughout: ``scale = max(|x|, axis) / qmax`` (clamped to
1e-8 so all-zero channels stay finite), ``q = clip(round(x / scale))``.
int8 uses qmax=127; fp8 (e4m3) uses qmax=448 and stores the scaled value
directly in the narrow float format (no rounding step needed — the cast
rounds).  fp8 is gated on the jax build exposing ``float8_e4m3fn``;
:func:`validate_member_dtype` rejects it when unavailable.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

# Bytes per parameter for each supported member execution dtype.  Serving
# activations stay fp32 regardless; this table governs param storage (and
# therefore H2D traffic and packing density in the allocator).
MEMBER_DTYPES = {"fp32": 4, "bf16": 2, "int8": 1, "fp8": 1}

_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)
_FP8_MAX = 448.0  # largest finite e4m3 value


def validate_member_dtype(name: str) -> str:
    """Check ``name`` is a supported member dtype; returns it unchanged."""
    if name not in MEMBER_DTYPES:
        raise ValueError(
            f"unknown member dtype {name!r}; expected one of "
            f"{sorted(MEMBER_DTYPES)}")
    if name == "fp8" and _FP8_DTYPE is None:
        raise ValueError("fp8 member dtype requires a jax build with "
                         "float8_e4m3fn support")
    return name


def dtype_bytes(name: Optional[str]) -> int:
    """Param bytes-per-element for a member dtype (None -> fp32)."""
    if name is None:
        return MEMBER_DTYPES["fp32"]
    return MEMBER_DTYPES[validate_member_dtype(name)]


def is_quantized_dtype(name: Optional[str]) -> bool:
    return name in ("int8", "fp8")


# precision ordering for PredictOptions.member_dtype ("at this precision or
# better"): fp32 > bf16 > int8 == fp8
_PRECISION_RANK = {"fp32": 3, "bf16": 2, "int8": 1, "fp8": 1}


def meets_precision(member_dtype: Optional[str],
                    floor: Optional[str]) -> bool:
    """True when a member executing at ``member_dtype`` (None -> fp32)
    satisfies a request's minimum-precision ``floor`` (None -> any)."""
    if floor is None:
        return True
    have = _PRECISION_RANK[member_dtype or "fp32"]
    return have >= _PRECISION_RANK[validate_member_dtype(floor)]


# --------------------------------------------------------------------------
# Core per-channel symmetric quantization
# --------------------------------------------------------------------------
def quantize_symmetric(x: jax.Array, axis: int = -1,
                       dtype: str = "int8") -> Tuple[jax.Array, jax.Array]:
    """Per-channel symmetric quantization along ``axis``.

    Returns ``(q, scale)`` with ``scale`` keeping a size-1 dim on ``axis``
    so ``q * scale`` broadcasts back to ``x``'s shape.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    if dtype == "int8":
        scale = jnp.maximum(amax / 127.0, 1e-8)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    elif dtype == "fp8":
        if _FP8_DTYPE is None:  # pragma: no cover - depends on jax build
            raise ValueError("fp8 unavailable in this jax build")
        scale = jnp.maximum(amax / _FP8_MAX, 1e-8)
        q = (xf / scale).astype(_FP8_DTYPE)
    else:
        raise ValueError(f"quantize_symmetric: unsupported dtype {dtype!r}")
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_symmetric` (lossy)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# KV-cache aliases (historical home: models/cache.py)
# --------------------------------------------------------------------------
def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-(head-)channel int8 over the trailing dim."""
    return quantize_symmetric(x, axis=-1, dtype="int8")


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    return dequantize(q, scale, dtype)


# --------------------------------------------------------------------------
# Weight-only param quantization (serving worker)
# --------------------------------------------------------------------------
# Quantized param trees wrap every leaf in a small dict so the original
# pytree structure is recoverable and the whole thing moves over H2D as one
# device_put: ``{"q": int8/fp8, "s": f32 scales}`` for quantized leaves,
# ``{"w": array}`` for passthrough.  Matrix-shaped leaves (ndim >= 2) are
# quantized per output channel (last axis); 1-D leaves (norm gains, biases,
# dt/A/D vectors) are precision-sensitive and tiny, so they ride along in
# fp32.  All wrapped-dict values are arrays, so device_put works unchanged.
def _is_wrapped(node: Any) -> bool:
    if not isinstance(node, dict):
        return False
    keys = set(node)
    return keys == {"q", "s"} or keys == {"w"}


def quantize_params(params: Any, dtype: str = "int8") -> Any:
    """Wrap a param pytree for reduced-precision storage.

    ``dtype`` in {"int8", "fp8"} quantizes matrix leaves per output channel;
    "bf16" casts matrix leaves; "fp32" wraps without conversion (useful for
    uniform handling).  Undo with :func:`dequantize_params`.
    """
    validate_member_dtype(dtype)

    def wrap(x):
        x = jnp.asarray(x)
        if x.ndim < 2 or dtype == "fp32":
            return {"w": x}
        if dtype == "bf16":
            return {"w": x.astype(jnp.bfloat16)}
        q, s = quantize_symmetric(x, axis=-1, dtype=dtype)
        return {"q": q, "s": s}

    return jax.tree_util.tree_map(wrap, params)


def dequantize_params(qparams: Any, dtype=jnp.float32) -> Any:
    """Recover a compute-dtype param pytree from :func:`quantize_params`.

    Traceable — call inside jit so dequantization fuses into the forward
    pass (weight-only quantization: storage and transfer are narrow, math
    is fp32).
    """
    def unwrap(node):
        if "w" in node:
            return node["w"].astype(dtype) if node["w"].dtype != dtype \
                else node["w"]
        return dequantize(node["q"], node["s"], dtype)

    return jax.tree_util.tree_map(unwrap, qparams, is_leaf=_is_wrapped)


def quantized_param_bytes(params: Any, dtype: str = "int8") -> int:
    """Bytes the wrapped tree occupies on device (q + scales + fp32 rest)."""
    wrapped = quantize_params(params, dtype)
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(wrapped)
               if hasattr(x, "dtype"))
