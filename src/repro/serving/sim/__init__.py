"""Trace-driven discrete-event simulator of the serving pipeline.

DESIGN.md §12.  The simulator replays request traces (recorded or
synthetic) through a virtual-clock model of the hot path — admission →
batcher/coalescing → DispatchQueue → predictor groups → completion — while
driving the *real* policy code: the real ``AdmissionQueue`` /
``DispatchQueue`` (and the EDF prototype), the real
``chunk_level``/``bucket_for`` packing rules, real ``Span``/``ChunkDesc``/
``SlotRef`` objects, and the real control plane (``balance_member``,
``BrownoutController.step``, ``LiveBench`` + ``bounded_greedy`` replans).
Only *time* is modelled: per-member chunk service times come from a
:class:`ServiceModel` fitted from recorded ``fake_delay_us`` runs (or a
LiveBench snapshot of one).

Everything is deterministic: one thread, one event heap with a sequence
tie-break, ``numpy`` generators seeded explicitly — the same seed and trace
produce a bit-identical event log and metrics.
"""
from repro.serving.sim.engine import SimSystem, SimWorker, WorkerSpec
from repro.serving.sim.events import EventLoop
from repro.serving.sim.forecast import DemandForecaster
from repro.serving.sim.service import ServiceModel
from repro.serving.sim.traces import (diurnal_trace, mmpp_trace,
                                      poisson_trace)
from repro.serving.sim.tuner import tune_dispatch_ahead

__all__ = ["SimSystem", "SimWorker", "WorkerSpec", "EventLoop",
           "ServiceModel", "DemandForecaster", "poisson_trace",
           "mmpp_trace", "diurnal_trace", "tune_dispatch_ahead"]
