"""Mixture-of-Experts layer: top-k router + GShard-style capacity dispatch.

The dispatch/combine formulation uses one-hot einsums over (group, capacity)
so that sharding experts over the "model" mesh axis induces all-to-all — the
TPU-native expert-parallel pattern — instead of gathers XLA cannot shard.
Tokens are processed in groups of ``GROUP`` so dispatch cost stays linear in
sequence length.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

GROUP = 512


def _router(x, w_router, top_k: int):
    """x: (T,D) -> (weights (T,k), idx (T,k), probs (T,E))."""
    logits = jnp.einsum("td,de->te", x, w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights.astype(x.dtype), idx, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    f = jnp.mean(jax.nn.one_hot(idx, num_experts, dtype=jnp.float32).sum(-2), axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def moe_ffn_dense(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dropless exact MoE: every expert computed for every token, combined by
    the top-k router weights.  O(E) compute — the correctness/CPU path."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    weights, idx, probs = _router(xt, p["router"], m.top_k)
    aux = load_balance_loss(probs, idx, m.num_experts) * m.router_aux_coef
    # (T,E) combine weights from scattered top-k
    wfull = jnp.zeros((xt.shape[0], m.num_experts), x.dtype).at[
        jnp.arange(xt.shape[0])[:, None], idx].add(weights)
    h = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, p["w_up"])
    eo = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    out = jnp.einsum("te,ted->td", wfull, eo).reshape(b, s, d)
    if m.shared_expert:
        sh = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["ws_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sh) * su, p["ws_down"])
    return out, aux


def moe_ffn(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    if m.impl == "dense":
        return moe_ffn_dense(cfg, p, x)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    weights, idx, probs = _router(xt, p["router"], m.top_k)
    aux = load_balance_loss(probs, idx, m.num_experts) * m.router_aux_coef

    g = min(GROUP, t)
    ng = t // g
    rem = t - ng * g
    if rem:                                    # pad to a whole number of groups
        pad = g - rem
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=0)
        # padded tokens get zero combine weight
        weights = weights * (jnp.arange(xt.shape[0]) < t)[:, None].astype(weights.dtype)
        ng += 1
    cap = max(1, int(m.capacity_factor * m.top_k * g / m.num_experts))
    cap = min(cap, g)

    xg = xt.reshape(ng, g, d)
    wg = weights.reshape(ng, g, m.top_k)
    ig = idx.reshape(ng, g, m.top_k)

    # §Perf variant "moe_ep": explicit GShard expert-parallel constraints.
    # Without them GSPMD falls back to involuntary full rematerialization of
    # the dispatch tensors (see EXPERIMENTS.md §Perf / llama4-scout).
    from repro import runtime_flags
    _mesh = runtime_flags.SHARDING_OPTS.get("moe_constraints")

    def _c(t, *spec):
        if _mesh is None:
            return t
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.launch.mesh import batch_axes
        bax = batch_axes(_mesh)
        bax = bax if len(bax) > 1 else (bax[0] if bax else None)
        full = []
        for dim, s in enumerate(spec):
            s = bax if s == "B" else s
            size = 1
            for a in ((s,) if isinstance(s, str) else (s or ())):
                size *= _mesh.shape[a]
            full.append(s if s and t.shape[dim] % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(_mesh, PartitionSpec(*full)))

    xg = _c(xg, "B", None, None)

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(ig, m.num_experts, dtype=jnp.int32)      # (ng,g,k,E)
    # rank among same-expert assignments, k-major so higher-priority k wins slots
    flat = onehot.transpose(0, 2, 1, 3).reshape(ng, g * m.top_k, m.num_experts)
    ranks = jnp.cumsum(flat, axis=1) - flat                          # (ng,g*k,E)
    pos = (ranks * flat).sum(-1).reshape(ng, m.top_k, g).transpose(0, 2, 1)
    keep = pos < cap
    expert_of = ig
    # dispatch tensor (ng, g, E, C)
    disp = (jax.nn.one_hot(expert_of, m.num_experts, dtype=xt.dtype)[..., None] *
            jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                           dtype=xt.dtype)[..., None, :-1]).sum(2)   # sum over k
    comb = (wg[..., None, None] *
            jax.nn.one_hot(expert_of, m.num_experts, dtype=xt.dtype)[..., None] *
            jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                           dtype=xt.dtype)[..., None, :-1]).sum(2)

    disp = _c(disp, "B", None, "model", None)
    comb = _c(comb, "B", None, "model", None)
    ex = jnp.einsum("tgec,tgd->tecd", disp, xg)                      # (ng,E,C,D)
    ex = _c(ex, "B", "model", None, None)         # all-to-all: tokens -> experts
    h = jnp.einsum("tecd,edf->tecf", ex, p["w_gate"])
    u = jnp.einsum("tecd,edf->tecf", ex, p["w_up"])
    eo = jnp.einsum("tecf,efd->tecd", jax.nn.silu(h) * u, p["w_down"])
    eo = _c(eo, "B", "model", None, None)
    out = jnp.einsum("tgec,tecd->tgd", comb, eo)                     # (ng,g,D)
    out = _c(out, "B", None, None)
    out = out.reshape(-1, d)[:t].reshape(b, s, d)

    if m.shared_expert:
        sh = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["ws_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sh) * su, p["ws_down"])
    return out, aux
