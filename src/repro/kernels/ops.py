"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad head_dim to a multiple of 128 (MXU lane alignment) and seq to block
    multiples, then slice results back;
  * pre-apply the softmax scale on q so zero-padding of head_dim cannot
    change results;
  * select interpret mode automatically off-TPU (`pallas_enabled()` reports
    whether the compiled TPU path is active).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import ensemble_combine as _comb
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd

_FORCE_INTERPRET = None  # tests can monkeypatch via set_interpret()


def set_interpret(value):
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def pallas_enabled() -> bool:
    return not _interpret()


def pow2_clamp(n: int, lo: int, hi: int) -> int:
    """Next power of two >= n, clamped to [lo, hi] (block-size selection)."""
    return min(hi, max(lo, 1 << max(n - 1, 1).bit_length()))


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd); scale 1/sqrt(hd)."""
    s, hd = q.shape[1], q.shape[3]
    q = q * (hd ** -0.5)
    bq = pow2_clamp(s, 8, _fa.BLOCK_Q)
    bkv = min(_fa.BLOCK_KV, bq)
    qp = _pad_to(_pad_to(q, 1, bq), 3, 128)
    kp = _pad_to(_pad_to(k, 1, bkv), 3, 128)
    vp = _pad_to(_pad_to(v, 1, bkv), 3, 128)
    sp = max(qp.shape[1], kp.shape[1])
    qp, kp, vp = (_pad_to(t, 1, sp) for t in (qp, kp, vp))
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              block_q=min(bq, sp), block_kv=min(bkv, sp),
                              valid_len=s, interpret=_interpret())
    return out[:, :s, :, :hd]


@jax.jit
def decode_attention(q, k, v, valid):
    """q: (B,1,H,hd), k/v: (B,L,KV,hd), valid: (L,) bool -> (B,1,H,hd)."""
    hd, L = q.shape[3], k.shape[1]
    q = q * (hd ** -0.5)
    bkv = pow2_clamp(L, 8, _dec.BLOCK_KV)
    qp = _pad_to(q, 3, 128)
    kp = _pad_to(_pad_to(k, 1, bkv), 3, 128)
    vp = _pad_to(_pad_to(v, 1, bkv), 3, 128)
    validp = _pad_to(valid.astype(jnp.int32), 0, bkv)
    out = _dec.decode_attention(qp, kp, vp, validp, block_kv=bkv,
                                interpret=_interpret())
    return out[:, :, :, :hd]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, bmat, cmat, *, chunk: int = 64):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), bmat/cmat: (B,S,N) -> (B,S,H,P)."""
    s = x.shape[1]
    xp = _pad_to(x, 1, chunk)
    dtp = _pad_to(dt, 1, chunk)
    bp = _pad_to(bmat, 1, chunk)
    cp = _pad_to(cmat, 1, chunk)
    out = _ssd.ssd_scan(xp, dtp, A, bp, cp, chunk=chunk, interpret=_interpret())
    return out[:, :s]


def _combine_blocks(seg: int, c: int):
    """Block sizes legal for the TPU kernel at ANY (seg, C): the seg block is
    a power of two in [8, BLOCK_SEG] (sublane multiple), the class block a
    multiple of 128 in [128, BLOCK_C] (lane width).  Inputs are padded up to
    block multiples, so arbitrary segment sizes never hit the kernel's
    divisibility assert."""
    return (pow2_clamp(seg, 8, _comb.BLOCK_SEG),
            pow2_clamp(c, 128, _comb.BLOCK_C))


@jax.jit
def ensemble_combine(preds, weights):
    """preds: (M, seg, C), weights: (M,) -> (seg, C)."""
    seg, c = preds.shape[1], preds.shape[2]
    bs, bc = _combine_blocks(seg, c)
    pp = _pad_to(_pad_to(preds, 1, bs), 2, bc)
    out = _comb.ensemble_combine(pp, weights, block_seg=bs, block_c=bc,
                                 interpret=_interpret())
    return out[:seg, :c]


@jax.jit
def ensemble_accumulate(partial, preds, weights):
    """Accumulate-into-partial combine (DESIGN.md §4): ``partial (seg, C)``
    + ``preds (M, seg, C)`` weighted by ``weights (M,)`` -> (seg, C)."""
    seg, c = preds.shape[1], preds.shape[2]
    bs, bc = _combine_blocks(seg, c)
    pp = _pad_to(_pad_to(preds, 1, bs), 2, bc)
    part = _pad_to(_pad_to(partial.astype(preds.dtype), 0, bs), 1, bc)
    out = _comb.ensemble_combine(pp, weights, part, block_seg=bs, block_c=bc,
                                 interpret=_interpret())
    return out[:seg, :c]


@jax.jit
def ensemble_accumulate_quant(partial, q, scales, weights):
    """Fused dequant-weight-accumulate: ``partial (seg, C) f32`` +
    Σ_m ``w_m · (q_m · s_m)`` with ``q (M, seg, C)`` int8/fp8 and per-row
    symmetric ``scales (M, seg) f32`` -> (seg, C) f32.

    Member predictions cross VMEM in their narrow storage dtype; the seg
    block floor is 32 (int8 sublane tile) rather than 8."""
    m, seg, c = q.shape
    bs = pow2_clamp(seg, 32, _comb.BLOCK_SEG)
    bc = pow2_clamp(c, 128, _comb.BLOCK_C)
    qp = _pad_to(_pad_to(q, 1, bs), 2, bc)
    sp = _pad_to(scales.astype(jnp.float32), 1, bs)
    # replicate the per-row scale across one lane tile so the kernel reads
    # it in (sublane, lane) layout without a transpose
    sp = jnp.broadcast_to(sp[:, :, None], sp.shape + (128,))
    part = _pad_to(_pad_to(partial.astype(jnp.float32), 0, bs), 1, bc)
    out = _comb.ensemble_combine_quant(part, qp, sp, weights, block_seg=bs,
                                       block_c=bc, interpret=_interpret())
    return out[:seg, :c]
