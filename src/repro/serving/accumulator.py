"""The prediction accumulator (paper §II.C.2).

Consumes {s, m, P} messages and folds them into the ensemble prediction:
``Y[start(s):end(s)] += P / M`` for averaging — or, with
``combine="pallas"``, buffers a segment's M member predictions and fuses the
weighted combine in the ensemble_combine Pallas kernel (DESIGN.md §7.4).
Other rules: "weighted" (per-member weights), "vote" (majority voting on
argmax).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.serving import segments as seg
from repro.serving.segments import Message


class PredictionAccumulator:
    def __init__(self, prediction_queue: "queue.Queue[Message]",
                 num_models: int, *, combine: str = "mean",
                 weights: Optional[np.ndarray] = None):
        self.q = prediction_queue
        self.M = num_models
        self.combine = combine
        self.weights = (np.asarray(weights, np.float32) if weights is not None
                        else np.full(num_models, 1.0 / num_models, np.float32))
        if combine == "mean":
            self.weights = np.full(num_models, 1.0 / num_models, np.float32)
        self.ready_count = 0
        self.oom = threading.Event()
        self.all_ready = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # per-request state
        self.Y: Optional[np.ndarray] = None
        self.segment_size = 0
        self.nb_samples = 0
        self._remaining = 0
        self._seg_buffers: Dict[int, List[Optional[np.ndarray]]] = {}
        self.done = threading.Event()

    # ---- request lifecycle ----------------------------------------------------
    def begin(self, nb_samples: int, num_classes: int, segment_size: int,
              members=None):
        """``members``: optional subset of model ids answering this request
        (paper §I.B "ensemble selection"); weights renormalize over them."""
        members = list(range(self.M)) if members is None else list(members)
        self._members = members
        wsum = float(self.weights[members].sum())
        self._active_weights = {m: float(self.weights[m]) / max(wsum, 1e-12)
                                for m in members}
        self.Y = np.zeros((nb_samples, num_classes), np.float32)
        self.nb_samples = nb_samples
        self.segment_size = segment_size
        self._remaining = seg.num_segments(nb_samples, segment_size) * len(members)
        self._seg_buffers = {}
        self.done.clear()
        if self._remaining == 0:
            self.done.set()

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError("prediction accumulator timed out")
        return self.Y

    # ---- the accumulation loop -------------------------------------------------
    def start(self):
        self._thread = threading.Thread(target=self._run, name="accumulator",
                                        daemon=True)
        self._thread.start()

    def stop(self):
        self.q.put(None)
        if self._thread:
            self._thread.join(10.0)

    def _run(self):
        while True:
            msg = self.q.get()
            if msg is None:
                return
            if msg.s == seg.READY:
                self.ready_count += 1
                if self.ready_count >= self._expected_ready():
                    self.all_ready.set()
                continue
            if msg.s == seg.OOM and msg.m is None:
                self.oom.set()
                self.done.set()
                continue
            self._accumulate(msg)

    _expected_ready_count = None

    def expect_ready(self, n: int):
        self._expected_ready_count = n
        if self.ready_count >= n:
            self.all_ready.set()

    def _expected_ready(self) -> int:
        return self._expected_ready_count or 1

    def _accumulate(self, msg: Message):
        lo = seg.start(msg.s, self.segment_size)
        hi = seg.end(msg.s, self.segment_size, self.nb_samples)
        members = getattr(self, "_members", list(range(self.M)))
        weights = getattr(self, "_active_weights",
                          {m: float(self.weights[m]) for m in members})
        if self.combine in ("mean", "weighted"):
            # the paper's one-liner: Y[start:end] += P / M (weighted general form)
            self.Y[lo:hi] += msg.P * weights[msg.m]
        elif self.combine == "vote":
            onehot = np.zeros_like(self.Y[lo:hi])
            onehot[np.arange(hi - lo), msg.P.argmax(axis=1)] = 1.0 / len(members)
            self.Y[lo:hi] += onehot
        elif self.combine == "pallas":
            buf = self._seg_buffers.setdefault(msg.s, {})
            buf[msg.m] = msg.P
            if len(buf) == len(members):
                from repro.kernels import ops as kops
                import jax.numpy as jnp
                stacked = jnp.asarray(np.stack([buf[m] for m in members]))
                w = jnp.asarray(np.array([weights[m] for m in members],
                                         np.float32))
                self.Y[lo:hi] = np.asarray(kops.ensemble_combine(stacked, w))
                del self._seg_buffers[msg.s]
        else:
            raise ValueError(f"unknown combine rule {self.combine!r}")
        self._remaining -= 1
        if self._remaining == 0:
            self.done.set()
