"""Quickstart: the paper's whole pipeline in ~80 lines.

1. Build a heterogeneous ensemble of (reduced) assigned-pool LMs.
2. Optimize the allocation matrix (Algorithm 1 -> Algorithm 2).
3. Deploy the asynchronous inference system behind the EnsembleClient
   facade and serve predictions — sync, with per-request options
   (priority / deadline / member subset), streaming per-segment partials,
   and a prediction cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationOptimizer, MeasuredBench, host_cpus
from repro.serving import (EnsembleClient, PredictionCache, PredictOptions,
                           InferenceSystem)

SEQ = 16


def main():
    # 1. the ensemble: 2 heterogeneous members (fast demo; see serve_ensemble
    #    for the full ENS4/ENS12 setups)
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    print("ensemble:", [c.name for c in cfgs])

    # 2. optimize the allocation matrix on 2 logical devices
    devices = host_cpus(2, memory_bytes=4 * 1024 ** 3)
    calib = np.random.default_rng(0).integers(
        0, cfgs[0].vocab_size, (64, SEQ)).astype(np.int32)
    bench = MeasuredBench(cfgs, params, calib, segment_size=32)
    opt = AllocationOptimizer(cfgs, devices, bench, max_iter=1, max_neighs=4,
                              batch_sizes=(8, 16), seq=SEQ)
    result = opt.optimize()
    print(f"\nAlgorithm 1 (worst-fit) throughput: {result.wfd_score:.1f} samples/s")
    print(f"Algorithm 2 (greedy)    throughput: {result.final_score:.1f} samples/s")
    print("\nallocation matrix (paper Table II style):")
    print(result.matrix.pretty())

    # 3. deploy and serve through the one request facade
    X = np.random.default_rng(1).integers(
        0, cfgs[0].vocab_size, (40, SEQ)).astype(np.int32)
    with InferenceSystem(cfgs, params, result.matrix, segment_size=32,
                         max_seq=SEQ) as system:
        client = EnsembleClient(system, cache=PredictionCache(capacity=1024))

        # sync, full ensemble
        Y = client.predict(X)
        print(f"\nserved {X.shape[0]} samples -> ensemble predictions {Y.shape}")
        print("top-1 classes of first 8 samples:", Y[:8].argmax(1).tolist())

        # per-request options: a latency-sensitive call on a member subset
        # with a deadline — jumps the admission queue, fails fast if late
        y_fast = client.predict(X[:4], PredictOptions(
            priority="high", deadline_ms=10_000, members=[0]))
        print("member-0-only (high priority):", y_fast.argmax(1).tolist())

        # streaming partials: segments arrive as their ensemble rows close
        done = []
        client.predict_stream(
            X, lambda s, lo, hi, Y_seg: done.append((s, hi - lo))
        ).result(60.0)
        print("streamed segments (id, rows):", sorted(done))

        # redundant requests are answered from the cache
        client.predict(X)
        print("cache after repeat:", client.metrics()["cache"])

    # 4. fault tolerance (DESIGN.md §10): with supervise=True a worker
    #    failure is contained to its instance instead of the paper's
    #    all-or-nothing shutdown.  Inject a deterministic crash into one of
    #    member 0's two data-parallel siblings: the supervisor quarantines
    #    it and replays its outstanding chunks on the survivor — zero lost
    #    requests, full quality.  With tracing=True the flight recorder
    #    (DESIGN.md §13) captures the whole drill as per-chunk span
    #    timelines — the quarantine and chunk replay show up as annotated
    #    instants on the admission track.
    import tempfile
    from repro.core import AllocationMatrix
    from repro.serving import FaultPlan, FaultSpec
    alloc = AllocationMatrix(devices, [c.name for c in cfgs],
                             np.array([[8, 8], [8, 0]]))
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=2,
                             worker="w1.0"))
    with InferenceSystem(cfgs, params, alloc, segment_size=32, max_seq=SEQ,
                         supervise=True, watchdog_s=5.0, retry_budget=2,
                         fault_plan=fp, tracing=True) as system:
        hs = [system.predict_async(X) for _ in range(6)]
        quals = [(h.result(120.0).shape[0], h.quality) for h in hs]
        c = system.serving_counters()
        print(f"\nfault injected: worker_crashes="
              f"{c.get('worker_crashes', 0):.0f} "
              f"quarantines={c.get('quarantines', 0):.0f} "
              f"segments_replayed={c.get('segments_replayed', 0):.0f}")
        print("all requests served at quality:", [q for _, q in quals])
        # dump the drill's trace as Chrome-trace / Perfetto JSON — open it
        # at https://ui.perfetto.dev (or chrome://tracing) to see each
        # request's admission -> pack -> dispatch -> predict -> transfer ->
        # combine timeline, with the replay annotations on the faulted
        # worker.  A live deployment serves the same JSON at GET /v2/trace
        # (serve.py --trace-out / --flight-recorder).
        trace_path = os.path.join(tempfile.gettempdir(),
                                  "fault_drill_trace.json")
        trace = EnsembleClient(system).dump_trace(trace_path)
        replay = [e for e in trace["traceEvents"]
                  if e.get("name") == "quarantine_replay"]
        print(f"flight recorder: {len(trace['traceEvents'])} events -> "
              f"{trace_path} (quarantine_replay instants: {len(replay)}; "
              f"load it at https://ui.perfetto.dev)")

    # 5. overload brownout (DESIGN.md §11): when offered load outruns
    #    capacity, the BrownoutController degrades *quality* instead of
    #    latency — it folds queue depth / p99 / loss counters into one
    #    pressure signal and, through hysteresis, serves cheaper member
    #    subsets (accuracy-elastic tiers).  Drive the control law by hand:
    from repro.serving import BrownoutController
    with InferenceSystem(cfgs, params, alloc, segment_size=32,
                         max_seq=SEQ) as system:
        ctl = BrownoutController(system, tiers=[(0, 1), (0,)],
                                 demote_inflight=False, feasibility=False)
        ctl.step(2.0)
        ctl.step(2.0)               # two high-pressure ticks: level 1
        h = system.predict_async(X)         # planned against the cheap tier
        Y_tier = h.result(60.0)
        print(f"\nbrownout drill: level={ctl.level} "
              f"tier quality={h.quality:.2f} "
              f"(served {Y_tier.shape[0]} rows on the cheap member)")
        for _ in range(10):
            ctl.step(0.0)           # sustained calm: back to level 0
        print(f"recovered to level {ctl.level}; "
              f"stats={ {k: v for k, v in ctl.stats().items() if k != 'tiers'} }")

    # 6. record a trace, replay it in the simulator (DESIGN.md §12):
    #    attach a TraceRecorder to the live system, then re-run the exact
    #    offered load through the discrete-event model — the same policy
    #    code under a virtual clock, so what-ifs (a different allocation,
    #    dispatch-ahead K, the EDF prototype) answer in milliseconds.
    from repro.serving.sim import ServiceModel, SimSystem, WorkerSpec
    from repro.serving.trace import TraceRecorder
    with InferenceSystem(cfgs, params, alloc, segment_size=32,
                         max_seq=SEQ) as system:
        rec = TraceRecorder()               # or launch/serve.py --record-trace
        system.trace_recorder = rec
        client = EnsembleClient(system)
        client.predict(X)
        client.predict(X[:4], PredictOptions(priority="high", members=[0]))
    svc = ServiceModel.from_delays({0: 500, 1: 500})   # 500us per chunk
    sim = SimSystem(svc, [WorkerSpec(0, 16), WorkerSpec(1, 16)],
                    segment_size=32).run(rec.events())
    r = sim.results()
    print(f"\nreplayed {r['offered']} recorded requests in-sim: "
          f"completed={r['completed']} p99={r['p99_ms']:.2f}ms "
          f"(deterministic; see benchmarks/sim_bench.py for the "
          f"forecast/tuner/EDF studies)")

    # 7. quantized members (DESIGN.md §14): int8 params with per-channel
    #    scales pack ~2-4x more members per device and feed the fused
    #    dequant-weight-accumulate combine epilogue; outputs stay within
    #    int8 tolerance of fp32.  From the CLI the same knob is
    #    `python -m repro.launch.serve --member-dtype int8` (or a
    #    per-member list like `--member-dtype int8,fp32`).
    with InferenceSystem(cfgs, params, alloc, segment_size=32, max_seq=SEQ,
                         member_dtypes=["int8", "int8"],
                         combine="pallas") as system:
        Y_q = EnsembleClient(system).predict(X)
        agree = float((Y_q.argmax(1) == Y.argmax(1)).mean())
        print(f"\nquantized ensemble (int8 + fused combine): "
              f"{Y_q.shape[0]} rows, top-1 agreement vs fp32 "
              f"{agree:.2f}")

    # Going further: the allocation above is frozen at deploy time.  When
    # the live workload drifts (one member runs hot, traffic spikes), attach
    # the online reconfiguration controller — live replanning + instance
    # migration + cross-worker work stealing (DESIGN.md §8):
    #     python examples/serve_ensemble.py --reconfig
    #     python -m repro.launch.serve --reconfig
    # The serving launcher runs supervised by default; the fault-tolerance
    # knobs (DESIGN.md §10) are --no-supervise, --watchdog-s,
    # --retry-budget, --nan-guard, and repeatable --fault SPECs for chaos
    # drills, e.g.:
    #     python -m repro.launch.serve \
    #         --fault stage=predictor,after=100,worker=w0.0
    # Overload robustness (DESIGN.md §11) adds --brownout, --tier-table,
    # --cascade-margin and --admission-budget-mib; a sustained-overload
    # drill slows one member and watches the 'brownout' block in /metrics:
    #     python -m repro.launch.serve --brownout --admission-budget-mib 64 \
    #         --fault stage=predictor,kind=slow,stall_s=0.004,worker=w1


if __name__ == "__main__":
    main()
