"""Deliverable (g) report: the roofline table from the dry-run artifacts —
one row per (arch x shape x mesh), three terms + dominant bottleneck."""
from __future__ import annotations

from repro.launch.roofline import load_rows


def run(csv=True):
    rows = load_rows()
    if csv:
        print("roofline:arch,shape,mesh,compute_s,memory_s,collective_s,"
              "dominant,useful_ratio")
        for r in rows:
            print(f"roofline:{r.arch},{r.shape},{r.mesh},{r.compute_s:.3e},"
                  f"{r.memory_s:.3e},{r.collective_s:.3e},{r.dominant},"
                  f"{r.useful_ratio:.2f}")
    return rows


if __name__ == "__main__":
    run()
