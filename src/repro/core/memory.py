"""Memory model: does an allocation matrix fit? (paper's ``fit_mem``).

Per-worker footprint = params + activation workspace (batch-dependent) +
decode KV/SSM cache (batch- and seq-dependent — our beyond-paper extension
for stateful LLM serving, DESIGN.md §9.3).

Param storage is dtype-size-aware (DESIGN.md §14): a member executing at
int8/fp8 holds its weights at 1 byte/param (+~3% for the per-channel scales)
while activations stay at the compute dtype, so quantized members roughly
double worst-fit packing density.  Pass ``member_dtypes`` (one dtype name
per model, None entries meaning fp32) to the allocation-level predicates.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.allocation import AllocationMatrix
from repro.core.devices import DeviceSpec
from repro.kernels.quant import dtype_bytes as _param_dtype_bytes

# per-channel scale overhead of the quantized param layout (one f32 per
# output channel; ~1/32 of the int8 payload at typical channel widths)
_SCALE_OVERHEAD = 1.03


def _param_bytes_per_elem(member_dtype: Optional[str],
                          dtype_bytes: int) -> float:
    """Bytes per param element for a member dtype (None -> the activation
    dtype, preserving the historical fp32-params assumption)."""
    if member_dtype is None:
        return dtype_bytes
    b = _param_dtype_bytes(member_dtype)
    return b * _SCALE_OVERHEAD if b == 1 else b


def worker_bytes(cfg: ModelConfig, batch: int, seq: int,
                 dtype_bytes: int = 4, *, serving_cache_len: int = 0,
                 member_dtype: Optional[str] = None) -> int:
    """Footprint of one worker (one model instance at one batch size)."""
    params = int(cfg.param_count()
                 * _param_bytes_per_elem(member_dtype, dtype_bytes))
    # activation workspace: residual + mixer + mlp peaks per layer (x2 for
    # double-buffering); heads term covers attention q/k/v blocks
    per_tok = (4 * cfg.d_model
               + (cfg.d_ff if cfg.moe is None else
                  cfg.moe.top_k * cfg.moe.d_ff_expert +
                  (cfg.moe.d_ff_shared if cfg.moe.shared_expert else 0))
               + 2 * cfg.num_heads * cfg.hd
               + (2 * cfg.d_inner if cfg.ssm else 0))
    acts = 2 * batch * seq * per_tok * dtype_bytes
    logits = batch * cfg.padded_vocab * dtype_bytes
    cache = cfg.kv_cache_bytes(batch, serving_cache_len or seq, 2) \
        if serving_cache_len else 0
    return params + acts + logits + cache


def device_usage(alloc: AllocationMatrix, cfgs: Sequence[ModelConfig],
                 seq: int, dtype_bytes: int = 4,
                 member_dtypes: Optional[Sequence[Optional[str]]] = None
                 ) -> List[int]:
    """Bytes used per device under matrix ``alloc``."""
    usage = [0] * len(alloc.devices)
    for d, m, batch in alloc.workers():
        usage[d] += worker_bytes(
            cfgs[m], batch, seq, dtype_bytes,
            member_dtype=member_dtypes[m] if member_dtypes else None)
    return usage


def fit_mem(alloc: AllocationMatrix, cfgs: Sequence[ModelConfig], seq: int,
            dtype_bytes: int = 4,
            member_dtypes: Optional[Sequence[Optional[str]]] = None) -> bool:
    """The paper's feasibility predicate."""
    usage = device_usage(alloc, cfgs, seq, dtype_bytes, member_dtypes)
    return all(u <= dev.memory_bytes
               for u, dev in zip(usage, alloc.devices))


def remaining_memory(alloc: AllocationMatrix, cfgs: Sequence[ModelConfig],
                     seq: int, dtype_bytes: int = 4,
                     member_dtypes: Optional[Sequence[Optional[str]]] = None
                     ) -> List[int]:
    usage = device_usage(alloc, cfgs, seq, dtype_bytes, member_dtypes)
    return [dev.memory_bytes - u for u, dev in zip(usage, alloc.devices)]
