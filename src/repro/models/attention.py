"""GQA self-attention (qk-norm, RoPE, sliding window), cross-attention, and
cached decode attention.

Two execution paths:
  * pure-jnp (default, shardable everywhere).  Long sequences use an online-
    softmax scan over KV chunks so the compiled memory footprint is O(S·chunk),
    never O(S^2) — the jnp analogue of the Pallas flash kernel.
  * Pallas (``repro.kernels``) when ``repro.kernels.ops.pallas_enabled()`` —
    the TPU target path, validated in interpret mode by tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30
_CHUNK = 512          # KV chunk for the online-softmax scan
_DENSE_MAX = 2048     # sequences up to this use the plain masked einsum


def project_qkv(cfg: ModelConfig, p, x, kv_src=None):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,Skv,KV,hd)."""
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating each kv head."""
    b, s, kv, hd = k.shape
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jax.Array:
    """(Sq,Sk) additive bias from position vectors."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def dense_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0,
                    scale: Optional[float] = None) -> jax.Array:
    """Plain masked attention.  q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd)."""
    h = q.shape[2]
    k, v = _expand_kv(k, h), _expand_kv(v, h)
    scale = scale or q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    logits = logits + _mask_bias(q_pos, k_pos, causal, window)[None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0,
                      chunk: int = _CHUNK) -> jax.Array:
    """Online-softmax attention scanning KV chunks; O(Sq*chunk) live memory."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if sk % chunk:                                   # pad kv to chunk multiple
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    nk = k.shape[1] // chunk
    k = _expand_kv(k, h).reshape(b, nk, chunk, h, hd)
    v = _expand_kv(v, h).reshape(b, nk, chunk, h, hd)
    k_pos = k_pos.reshape(nk, chunk)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry                            # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd)
        kc, vc, kp = xs
        logits = jnp.einsum("bqhk,bshk->bhqs", qf, kc.astype(jnp.float32))
        logits = logits + _mask_bias(q_pos, kp, causal, window)[None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha.transpose(0, 2, 1)[..., None] + \
            jnp.einsum("bhqs,bshk->bqhk", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, sq, h, hd), jnp.float32))
    from repro import runtime_flags
    (m, l, acc), _ = jax.lax.scan(
        body, init, (k.swapaxes(0, 1), v.swapaxes(0, 1), k_pos),
        unroll=runtime_flags.scan_unroll())
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def self_attention(cfg: ModelConfig, p, x, positions, *, window: int = 0,
                   use_kernel: bool = False) -> jax.Array:
    """Full-sequence causal attention for train/prefill.  x: (B,S,D)."""
    q, k, v = project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window)
    elif s <= _DENSE_MAX:
        out = dense_attention(q, k, v, positions[0] if positions.ndim > 1 else positions,
                              positions[0] if positions.ndim > 1 else positions,
                              causal=True, window=window)
    else:
        pos1 = positions[0] if positions.ndim > 1 else positions
        out = chunked_attention(q, k, v, pos1, pos1, causal=True, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention(cfg: ModelConfig, p, x, frontend, *, use_kernel: bool = False):
    """x: (B,S,D) attends to frontend embeddings (B,F,fdim).  No mask, no RoPE."""
    q, k, v = project_qkv(cfg, p, x, kv_src=frontend)
    sq, sk = x.shape[1], frontend.shape[1]
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    if max(sq, sk) <= _DENSE_MAX:
        out = dense_attention(q, k, v, qp, kp, causal=False)
    else:
        out = chunked_attention(q, k, v, qp, kp, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def decode_attention(cfg: ModelConfig, p, x, k_cache, v_cache, pos, *,
                     window: int = 0, use_kernel: bool = False,
                     k_scale=None, v_scale=None):
    """One-token attention against a cache.

    x: (B,1,D); k_cache/v_cache: (B,L,KV,hd) (ring buffer for SWA layers);
    pos: scalar int32 — absolute position of the new token.  With
    k_scale/v_scale ((B,L,KV,1) f32) the cache is int8 and is dequantized on
    read (beyond-paper §Perf: halves KV-streaming bytes).
    Returns (attn_out (B,1,D), new_k, new_v[, new_k_scale, new_v_scale]).
    """
    q, k_new, v_new = project_qkv(cfg, p, x)
    posv = jnp.full((x.shape[0], 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)
    quantized = k_scale is not None
    from repro import runtime_flags
    _fd_mesh = runtime_flags.SHARDING_OPTS.get("decode_cache_seq")
    if not quantized and _fd_mesh is not None and \
            not isinstance(_fd_mesh, bool) and \
            k_cache.shape[1] % _fd_mesh.shape["model"] == 0:
        # §Perf variant "cache_seqshard": shard_map flash-decoding over a
        # sequence-sharded cache (see parallel/collectives.flash_decode).
        from repro.parallel.collectives import flash_decode
        out, k_cache, v_cache = flash_decode(
            _fd_mesh, q, k_cache, v_cache, k_new, v_new, pos, window=window)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache
    L = k_cache.shape[1]
    slot = pos % L if window > 0 else pos            # ring buffer for SWA
    if quantized:
        from repro.models.cache import dequantize_kv, quantize_kv
        kq, ks = quantize_kv(k_new[:, 0])
        vq, vs = quantize_kv(v_new[:, 0])
        k_cache = jax.lax.dynamic_update_index_in_dim(k_cache, kq, slot, 1)
        v_cache = jax.lax.dynamic_update_index_in_dim(v_cache, vq, slot, 1)
        k_scale = jax.lax.dynamic_update_index_in_dim(k_scale, ks, slot, 1)
        v_scale = jax.lax.dynamic_update_index_in_dim(v_scale, vs, slot, 1)
        k_read = dequantize_kv(k_cache, k_scale)
        v_read = dequantize_kv(v_cache, v_scale)
    else:
        k_cache = jax.lax.dynamic_update_index_in_dim(
            k_cache, k_new[:, 0].astype(k_cache.dtype), slot, 1)
        v_cache = jax.lax.dynamic_update_index_in_dim(
            v_cache, v_new[:, 0].astype(v_cache.dtype), slot, 1)
        k_read, v_read = k_cache, v_cache
    # key positions: for ring buffers reconstruct absolute positions per slot
    idx = jnp.arange(L)
    if window > 0:
        # slot i holds absolute position: the latest p <= pos with p % L == i
        k_pos = pos - ((pos - idx) % L)
    else:
        k_pos = idx
    valid = (k_pos <= pos) & (k_pos >= 0)
    if window > 0:
        valid &= k_pos > pos - window
    if use_kernel and not quantized:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, k_read, v_read, valid)
    else:
        h = q.shape[2]
        kx, vx = _expand_kv(k_read, h), _expand_kv(v_read, h)
        logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                            kx.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, vx.astype(jnp.float32)).astype(q.dtype)
    attn = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if quantized:
        return attn, k_cache, v_cache, k_scale, v_scale
    return attn, k_cache, v_cache
