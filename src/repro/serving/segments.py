"""Segment protocol (paper §II.C.1) and the per-request descriptor.

Requests are split into fixed-size segments; only small descriptors flow
through the FIFO queues while the sample bytes live in the request's input
buffer.  Special ids: ``SHUTDOWN`` asks a worker to exit; workers emit
``Message(OOM/READY, ...)`` sentinels to the prediction accumulator.

Hot-path extensions (DESIGN.md §3):
  * every in-flight request owns a :class:`Request` descriptor carrying a
    *versioned* input buffer — a new request never reallocates a buffer a
    worker may still be reading (the seed's ``shared_x`` swap race);
  * messages are tagged with the request id ``rid`` so multiple requests can
    be in flight at once;
  * a message with ``m is None`` is a *device partial*: the weighted sum of
    ``count`` member predictions, pre-combined on one device
    (DESIGN.md §4) — the accumulator just adds it into Y.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

SHUTDOWN = -1          # segment-ids-queue sentinel: worker must exit
OOM = -1               # prediction-queue sentinel: device out of memory
READY = -2             # prediction-queue sentinel: worker initialized

DEFAULT_SEGMENT_SIZE = 128      # paper §III: fixed to 128


def num_segments(nb_samples: int, segment_size: int) -> int:
    return (nb_samples + segment_size - 1) // segment_size


def start(s: int, segment_size: int) -> int:
    return s * segment_size


def end(s: int, segment_size: int, nb_samples: int) -> int:
    return min((s + 1) * segment_size, nb_samples)


@dataclass
class Message:
    """The {s, m, P} triplet (paper §II.C.2), tagged with the request id.

    ``m is None`` (with ``s >= 0``) marks a device-partial message whose P
    already folds ``count`` weighted member predictions.  Sentinels use
    P=None."""
    s: int                       # segment id (or OOM / READY)
    m: Optional[int]             # model id; None = device partial
    P: Optional[np.ndarray]      # (end(s)-start(s), C) prediction matrix
    rid: int = 0                 # request id
    count: int = 1               # member contributions folded into P

    @property
    def is_sentinel(self) -> bool:
        return self.s < 0


@dataclass
class Request:
    """One in-flight predict() call.

    ``x`` is the request's own input buffer (rows ``[:n]`` valid).  Workers
    slice it zero-copy; because the buffer belongs to the request — not the
    system — growing a later request can never invalidate it mid-flight."""
    rid: int
    x: np.ndarray                       # (capacity >= n, seq) int32
    n: int                              # valid samples
    num_classes: int
    segment_size: int
    members: List[int]                  # active ensemble members
    weights: Dict[int, float]           # member -> normalized combine weight
    combine: str = "mean"

    def num_segments(self) -> int:
        return num_segments(self.n, self.segment_size)

    def bounds(self, s: int):
        return (start(s, self.segment_size),
                end(s, self.segment_size, self.n))
