"""HTTP wrapper + adaptive batching tests."""
import json
import threading
import urllib.request

import jax
import numpy as np
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.server import serve
from repro.serving.system import InferenceSystem

PORT = 8691
SEQ = 16


@pytest.fixture(scope="module")
def server():
    cfgs = ensemble("ENS4")[:1]
    params = [M.init_params(jax.random.PRNGKey(0), cfgs[0])]
    devs = host_cpus(1, memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [cfgs[0].name], np.array([[8]]))
    system = InferenceSystem(cfgs, params, alloc, segment_size=16, max_seq=SEQ)
    httpd, batcher = serve(system, port=PORT, max_wait_s=0.02)
    yield system
    httpd.shutdown()
    batcher.stop()
    system.shutdown()


def _get(path):
    return json.load(urllib.request.urlopen(f"http://127.0.0.1:{PORT}{path}"))


def _post(path, payload):
    req = urllib.request.Request(
        f"http://127.0.0.1:{PORT}{path}", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    return json.load(urllib.request.urlopen(req))


def test_health(server):
    r = _get("/health")
    assert r["status"] == "ok" and r["workers"] == 1


def test_allocation_endpoint(server):
    r = _get("/allocation")
    assert r["A"] == [[8]]


def test_predict_roundtrip(server):
    x = np.random.default_rng(0).integers(0, 512, (3, SEQ)).tolist()
    r = _post("/predict", {"tokens": x})
    y = np.asarray(r["predictions"])
    assert y.shape == (3, 512)
    assert np.isfinite(y).all()


def test_bad_request(server):
    req = urllib.request.Request(
        f"http://127.0.0.1:{PORT}/predict", data=b'{"tokens": [1,2,3]}',
        headers={"Content-Type": "application/json"})
    try:
        urllib.request.urlopen(req)
        assert False, "should have errored"
    except urllib.error.HTTPError as e:
        assert e.code == 400


def test_adaptive_batching_coalesces(server):
    """Concurrent small requests are served within one segment flush."""
    results = {}

    def call(i):
        x = np.random.default_rng(i).integers(0, 512, (2, SEQ)).tolist()
        results[i] = np.asarray(_post("/predict", {"tokens": x})["predictions"])

    threads = [threading.Thread(target=call, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert len(results) == 4
    for y in results.values():
        assert y.shape == (2, 512)
