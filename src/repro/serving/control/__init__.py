"""Online reconfiguration (DESIGN.md §8): the control plane that closes the
loop between the paper's offline allocator (worst-fit + bounded greedy) and
the live serving hot path.

* :class:`LiveBench` — an EWMA per-(member, device, bucket) latency profile
  plus per-member demand shares, fed by the workers and the broadcaster;
  callable as a ``Bench`` so the paper's Algorithm 2 can replan against the
  *live* workload instead of the offline calibration profile.
* :class:`ReconfigController` — a background thread that periodically
  re-runs the bounded greedy against the live profile and applies the
  allocation delta as live actions (spawn / drain / rebatch instances),
  and runs the work-stealing fast path between replans.
* :mod:`stealing` — re-routes queued descriptors from a deep admission
  queue to an idle data-parallel sibling, moving the device combiners'
  expected-row maps with them.
* :class:`Supervisor` — per-worker heartbeat/liveness sweep that contains
  instance failures (quarantine + chunk replay / graceful degradation,
  DESIGN.md §10) instead of the paper's all-or-nothing sentinel.
* :class:`BrownoutController` — overload robustness (DESIGN.md §11):
  pressure-driven quality tiers with hysteresis, cost-aware admission with
  computed Retry-After, mid-flight demotion and confidence-gated cascade.
"""
from repro.serving.control.controller import ReconfigController
from repro.serving.control.livebench import LiveBench
from repro.serving.control.overload import (BrownoutController, CascadeHandle,
                                            build_tier_table, estimate_drain_s)
from repro.serving.control.stealing import balance_member, steal_from
from repro.serving.control.supervisor import Supervisor

__all__ = ["ReconfigController", "LiveBench", "balance_member", "steal_from",
           "Supervisor", "BrownoutController", "CascadeHandle",
           "build_tier_table", "estimate_drain_s"]
