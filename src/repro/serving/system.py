"""The inference system core (paper §II.C): ``f(X, A) -> {Y, S}``.

"Deploy Mode": ``predict(X) -> Y`` serves requests.
"Benchmark Mode": ``benchmark(X) -> (Y, S)`` measures the throughput S of
allocation matrix A on calibration samples.

Processes (threads here — DESIGN.md §2): the *segment ids broadcaster*, the
*worker pool* and the *prediction accumulator*, wired by thread-safe FIFO
queues; sample bytes live in per-request input buffers, only small segment
descriptors travel through queues.

Hot-path architecture (DESIGN.md §§3-5):
  * every request owns a pooled input buffer (versioned swap — growing a
    later request can never invalidate a buffer workers still read);
  * (segment, model) pairs are striped round-robin across a model's
    data-parallel instances, which makes per-device contribution counts
    deterministic and enables the device-resident partial combine
    (``device_combine=True``): one accumulator message per device per
    segment instead of one per member per segment — striping is unchanged
    under coalescing, so row-count flush accounting still closes;
  * requests are tagged with ids and pipelined — up to ``max_in_flight``
    ``predict_async()`` calls overlap instead of serializing on the
    accumulator.  The window defaults to 16 so the coalescing batchers
    (``coalesce=True``, bounded ``max_wait_us`` linger) see rows from many
    small concurrent requests and can pack them into full compiled batches;
    ``quiesce()`` force-flushes any lingering partial batches.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocation import AllocationMatrix
from repro.serving.accumulator import PredictionAccumulator, RequestHandle
from repro.serving.admission import AdmissionBudget, AdmissionQueue
from repro.serving.combiner import DeviceCombiner
from repro.serving.metrics import StageTimers
from repro.serving.segments import (DEFAULT_SEGMENT_SIZE, FLUSH, OOM,
                                    FlushBarrier, SHUTDOWN, DeadlineExceeded,
                                    MemberUnavailable, Message, Overloaded,
                                    PredictOptions, Request, RetriesExhausted)
from repro.serving.worker import HEALTH_DEAD, Worker

_COMBINE_RULES = ("mean", "weighted", "vote", "pallas")


class InferenceSystem:
    def __init__(self, cfgs: Sequence[ModelConfig], params_list,
                 alloc: AllocationMatrix, *,
                 segment_size: int = DEFAULT_SEGMENT_SIZE,
                 combine: str = "mean",
                 weights: Optional[np.ndarray] = None,
                 fake: bool = False,
                 frontends: Optional[Dict[int, np.ndarray]] = None,
                 max_seq: int = 128,
                 use_kernel: bool = False,
                 ready_timeout: float = 300.0,
                 device_combine: bool = True,
                 max_in_flight: int = 16,
                 coalesce: bool = True,
                 max_wait_us: int = 500,
                 linger: str = "fixed",
                 fake_delay_us: int = 0,
                 dispatch_ahead: Optional[int] = None,
                 fault_plan=None,
                 supervise: bool = False,
                 watchdog_s: float = 5.0,
                 supervise_interval_s: float = 0.05,
                 retry_budget: int = 2,
                 nan_guard: bool = False,
                 admission_budget=None,
                 tracing: bool = False,
                 trace_capacity: int = 4096,
                 member_dtypes: Optional[Sequence[Optional[str]]] = None,
                 dispatch_queue: str = "fifo"):
        alloc.validate()
        self.cfgs = list(cfgs)
        self.alloc = alloc
        self.segment_size = segment_size
        self.max_seq = max_seq
        self.combine = combine
        self.device_combine = device_combine
        self.max_in_flight = max(1, max_in_flight)
        self.coalesce = coalesce
        self.max_wait_us = max_wait_us
        self.linger = linger
        # K outstanding async dispatches per worker: the committed
        # (non-preemptible) window — small K favors high-priority latency,
        # large K favors pipeline throughput (DESIGN.md §3)
        from repro.serving.worker import DISPATCH_AHEAD
        self.dispatch_ahead = DISPATCH_AHEAD if dispatch_ahead is None \
            else dispatch_ahead
        self.M = len(self.cfgs)
        # per-member execution precision (DESIGN.md §14): "fp32" (default),
        # "bf16", "int8" or "fp8".  Quantized members load per-channel-scaled
        # narrow params, emit (q, scale) logits into the fused combine
        # epilogue, and halve-to-quarter their allocator footprint.
        from repro.kernels.quant import validate_member_dtype
        if member_dtypes is None:
            self.member_dtypes: List[str] = ["fp32"] * self.M
        else:
            if len(member_dtypes) != self.M:
                raise ValueError(
                    f"member_dtypes needs {self.M} entries, "
                    f"got {len(member_dtypes)}")
            self.member_dtypes = [validate_member_dtype(dt or "fp32")
                                  for dt in member_dtypes]
        # dispatch-queue policy (ROADMAP item m): FIFO-within-priority
        # (default) or earliest-deadline-first, simulator-validated
        if dispatch_queue not in ("fifo", "edf"):
            raise ValueError(f"dispatch_queue must be 'fifo' or 'edf', "
                             f"got {dispatch_queue!r}")
        self.dispatch_queue = dispatch_queue
        if dispatch_queue == "edf":
            from repro.serving.admission import EDFDispatchQueue
            self._dispatch_queue_cls = EDFDispatchQueue
        else:
            self._dispatch_queue_cls = None      # worker default (FIFO)
        # retained for live instance spawn/drain (DESIGN.md §8)
        self._params_list = list(params_list)
        self._frontends = dict(frontends or {})
        self._fake = fake
        self._fake_delay_us = fake_delay_us
        self._use_kernel = use_kernel
        self.generation = 0              # bumped by each applied reconfig
        self.controller = None           # attached ReconfigController, if any
        self._profiler = None            # attached LiveBench sink, if any
        self.brownout = None             # attached BrownoutController (§11)
        self.trace_recorder = None       # attached TraceRecorder (§12)
        # global admitted-work budget (DESIGN.md §11 backpressure): an int
        # is a byte cap, an AdmissionBudget carries byte and/or row caps
        if admission_budget is None or \
                isinstance(admission_budget, AdmissionBudget):
            self.admission_budget = admission_budget
        else:
            self.admission_budget = AdmissionBudget(
                max_bytes=int(admission_budget))
        # fault tolerance (DESIGN.md §10): opt-in — unsupervised systems
        # keep the paper's §II.C.2 all-or-nothing sentinel semantics
        self._fault_plan = fault_plan
        self._nan_guard = nan_guard
        self.watchdog_s = watchdog_s
        self.retry_budget = retry_budget
        self.supervisor = None
        classes = {c.vocab_size for c in self.cfgs}
        if len(classes) != 1:
            raise ValueError(f"ensemble members disagree on class count: {classes}")
        self.num_classes = classes.pop()

        self.timers = StageTimers()
        # span tracing (DESIGN.md §13): the Tracer always exists so tracing
        # can be toggled at runtime; when disabled every emitter pays one
        # attribute check and no ring ever allocates
        from repro.serving.tracing import Tracer
        self.tracer = Tracer(enabled=tracing, capacity=trace_capacity)
        self.prediction_queue: "queue.Queue[Message]" = queue.Queue()
        self.accumulator = PredictionAccumulator(
            self.prediction_queue, self.M, combine=combine, weights=weights,
            timers=self.timers, on_complete=self._on_request_complete,
            tracer=self.tracer)

        # request submission / in-flight window / buffer pool
        self._submit_lock = threading.Lock()
        self._pool_lock = threading.Lock()
        self._buffer_pool: List[np.ndarray] = []
        self._inflight = threading.BoundedSemaphore(self.max_in_flight)
        self._next_rid = 0

        self.combiners: Dict[int, DeviceCombiner] = {}
        self.workers: List[Worker] = []
        self._instances: Dict[int, List[Worker]] = {m: [] for m in range(self.M)}
        for d, m, batch in alloc.workers():
            if device_combine and d not in self.combiners:
                self.combiners[d] = DeviceCombiner(
                    f"d{d}", self.prediction_queue, timers=self.timers,
                    tracer=self.tracer)
            w = self._make_worker(d, m, batch, generation=0)
            self.workers.append(w)
            self._instances[m].append(w)

        self.accumulator.expect_ready(len(self.workers))
        self.accumulator.start()
        for w in self.workers:
            w.start()
        if not self.accumulator.all_ready.wait(ready_timeout):
            raise TimeoutError("workers failed to initialize")
        self._shutdown = False
        if supervise:
            # lazy import: control.supervisor imports worker health codes
            from repro.serving.control.supervisor import Supervisor
            self.supervisor = Supervisor(
                self, watchdog_s=watchdog_s,
                interval_s=supervise_interval_s, retry_budget=retry_budget)
            for w in self.workers:       # contain crashes from the start
                w.on_crash = self.supervisor.on_worker_crash
            self.supervisor.start()

    # ---- live topology (online reconfiguration, DESIGN.md §8) ----------------
    def _make_worker(self, d: int, m: int, batch: int, *,
                     generation: int, oom_sentinel: bool = True) -> Worker:
        """Construct (and warm up) one worker; does NOT register it for
        routing.  The warm-up compile runs in the constructor, so a returned
        worker is immediately servable."""
        w = Worker(f"w{d}.{m}.g{generation}" if generation else f"w{d}.{m}",
                   self.cfgs[m], self._params_list[m],
                   self.alloc.devices[d], batch,
                   AdmissionQueue(), self.prediction_queue, m,
                   self.max_seq, self.segment_size, fake=self._fake,
                   frontend=self._frontends.get(m),
                   use_kernel=self._use_kernel,
                   combiner=self.combiners.get(d), timers=self.timers,
                   coalesce=self.coalesce, max_wait_us=self.max_wait_us,
                   linger=self.linger, generation=generation,
                   profiler=self._profiler, oom_sentinel=oom_sentinel,
                   fake_delay_us=self._fake_delay_us,
                   dispatch_ahead=self.dispatch_ahead,
                   fault_plan=self._fault_plan, nan_guard=self._nan_guard,
                   tracer=self.tracer,
                   member_dtype=self.member_dtypes[m],
                   dispatch_queue=self._dispatch_queue_cls)
        w.device_idx = d
        w.input_queue.trace_hook = self._trace_queue_event(w.worker_id)
        if self.supervisor is not None:   # supervised containment for live
            w.on_crash = self.supervisor.on_worker_crash   # spawns/respawns
        return w

    def _trace_queue_event(self, worker_id: str):
        """AdmissionQueue ``trace_hook`` for one worker: annotates the
        admission track with steal/drain migrations.  Plain enqueues are
        already covered by the submit span, so they return on one string
        compare."""
        tracer = self.tracer
        def hook(kind, items, level, _tr=tracer, _wid=worker_id):
            if kind == "enqueue" or not _tr.enabled or not items:
                return
            _tr.instant("admission", f"queue_{kind}",
                        rid=tuple(sorted({req.rid for req, _s in items})),
                        args={"worker": _wid, "units": len(items)})
        return hook

    def spawn_instance(self, d: int, m: int, batch_size: int, *,
                       generation: Optional[int] = None) -> Worker:
        """Live-add a data-parallel instance of member ``m`` on device ``d``
        at ``batch_size`` without touching in-flight requests.  The worker
        warms up (compiles) *before* it is atomically spliced into the
        routing tables, so the first request striped to it never waits on
        compilation.  Raises (without failing in-flight requests) when the
        device cannot host it."""
        if self._shutdown:
            raise RuntimeError("system is shut down")
        gen = self.generation if generation is None else generation
        if self.device_combine:
            # registered before any descriptor can route to the new worker
            # (_make_worker and _on_request_complete read self.combiners)
            with self._submit_lock:
                if d not in self.combiners:
                    self.combiners[d] = DeviceCombiner(
                        f"d{d}", self.prediction_queue, timers=self.timers,
                        tracer=self.tracer)
        # warm-up compile outside the routing lock: submission stays live
        w = self._make_worker(d, m, batch_size, generation=gen,
                              oom_sentinel=False)
        w.start()
        with self._submit_lock:
            if self._shutdown:
                registered = False        # shut down during our warm-up:
            else:                         # never splice into a dead system
                self.workers.append(w)
                self._instances[m].append(w)
                self.alloc.A[d, m] = batch_size
                registered = True
        if not registered:
            w.input_queue.put(SHUTDOWN)   # tear the probe worker down
            raise RuntimeError("system shut down during spawn_instance")
        return w

    def drain_instance(self, w: Worker, *, migrate: bool = True,
                       wait: bool = True, timeout: float = 60.0) -> None:
        """Retire a live worker without dropping in-flight work: the worker
        is removed from the routing tables (no new descriptors), its queued
        descriptors are migrated to data-parallel siblings (combiner
        expected-row maps move with them) or, with ``migrate=False``, drained
        in place, and a ``SHUTDOWN`` sentinel lets the pipeline finish
        everything already accepted before the threads exit."""
        from repro.serving.control.stealing import migrate_descriptors
        with self._submit_lock:
            if self._shutdown:
                # shutdown owns teardown: every worker drains its own queue
                # before exiting — migrating now would re-put descriptors
                # behind a sibling's SHUTDOWN, where they are discarded
                return
            inst = self._instances.get(w.model_idx, [])
            if w not in inst:
                return                    # already drained (idempotent)
            if len(inst) == 1:
                raise ValueError(
                    f"cannot drain {w.worker_id}: sole instance of member "
                    f"{w.model_idx} (every member must stay served)")
            inst.remove(w)
            self.workers.remove(w)
            if not any(x.device_idx == w.device_idx for x in inst):
                self.alloc.A[w.device_idx, w.model_idx] = 0
            if migrate:
                migrate_descriptors(self, w, inst)
        w.input_queue.put(SHUTDOWN)       # queued work (if any) drains first
        if wait:
            w.join(timeout)

    def quarantine_instance(self, w: Worker,
                            retry_budget: Optional[int] = None) -> None:
        """Contain a dead/stalled worker (DESIGN.md §10): remove it from
        routing atomically, then recover every outstanding unit it owned —
        its still-queued descriptors plus its in-flight ledger entries, a
        unit being exactly one or the other.

        With surviving data-parallel siblings the units are *resubmitted*
        (combiner expectations move with them, same as a drain migration);
        each affected request is charged one retry, and a request over its
        ``retry_budget`` fails with :class:`RetriesExhausted` instead.

        With no sibling (sole instance of the member) the units are
        *forgiven*: a per-unit forgiveness message lets the accumulator
        complete open requests with a degraded partial-ensemble combine,
        and the controller (if any) is asked to respawn the member.  Only
        when EVERY member has lost its last instance does the paper's
        global {-1, None, None} sentinel fire — nothing is left to degrade
        onto.

        Unlike :meth:`drain_instance` the pipeline is presumed dead: no
        SHUTDOWN is sent and no join is attempted — a stalled stage thread
        is leaked as a daemon, and the in-flight ledger pop-gate makes any
        late wakeup of it harmless (its completed contributions are
        skipped, never double-posted).  Idempotent; safe from the
        supervisor thread."""
        from repro.serving.control.stealing import _transfer
        budget = self.retry_budget if retry_budget is None else retry_budget
        exhausted: List[int] = []
        member_down = None
        with self._submit_lock:
            if self._shutdown:
                return                    # shutdown owns teardown
            inst = self._instances.get(w.model_idx, [])
            if w not in inst:
                return                    # already quarantined/drained
            inst.remove(w)
            self.workers.remove(w)
            if not any(x.device_idx == w.device_idx for x in inst):
                self.alloc.A[w.device_idx, w.model_idx] = 0
            self.timers.inc("quarantines")
            if self.tracer.enabled:
                self.tracer.instant("admission", "quarantine",
                                    args={"worker": w.worker_id})
            # the final health verdict persists in the gauge snapshot after
            # the worker leaves the routing tables (serving_gauges only
            # refreshes live workers)
            self.timers.gauge(f"health.{w.worker_id}", HEALTH_DEAD)
            # outstanding units: queued descriptors (never entered the
            # pipeline) + in-flight ledger entries (admitted, not yet
            # forwarded).  Popping a ledger key here CLAIMS the unit
            # against the worker's own sender — dict.pop is GIL-atomic,
            # so exactly one side wins (replay idempotency).
            units = list(w.input_queue.drain_descriptors())
            for key in list(w._ledger.keys()):
                req = w._ledger.pop(key, None)
                if req is not None:
                    units.append((req, key[1]))
            units = [(req, s) for req, s in units if not req.dropped()]
            if inst:
                # one retry charged per request per quarantine event (not
                # per unit — losing a worker is one failure)
                charged: Dict[int, Request] = {}
                for req, _ in units:
                    if req.rid not in charged:
                        req.retries += 1
                        charged[req.rid] = req
                exhausted = [rid for rid, req in charged.items()
                             if req.retries > budget]
                dead_rids = set(exhausted)
                replayed = 0
                for req, s in units:
                    if req.rid in dead_rids:
                        continue          # fail() below tears down maps
                    dst = inst[(s + req.rid) % len(inst)]
                    _transfer(req, s, w, dst)
                    dst.input_queue.put((req, s), req.priority)
                    replayed += 1
                if replayed:
                    self.timers.inc("segments_replayed", replayed)
                if self.tracer.enabled:
                    # chunk-replay provenance: which requests were re-striped
                    # off the quarantined worker, and how many units moved
                    self.tracer.instant(
                        "admission", "quarantine_replay",
                        rid=tuple(sorted({req.rid for req, _ in units})),
                        args={"worker": w.worker_id, "replayed": replayed,
                              "exhausted": len(exhausted)})
            elif all(len(v) == 0 for v in self._instances.values()):
                # last instance of the last member: nothing left to degrade
                # onto — the paper's global sentinel applies (and it must be
                # the ONLY message, or forgiveness would complete requests
                # at quality 0 before the sentinel fails them)
                self.prediction_queue.put(Message(OOM, None, None))
            else:
                member_down = (w.model_idx, w.device_idx, w.batch_size)
                for req, s in units:
                    if w.combiner is not None and \
                            not w.combiner.unexpect(req, s):
                        continue          # request already torn down
                    # forgiveness message: P=None with s >= 0 — the
                    # accumulator debits the member's rows for this
                    # segment and tracks the missing weight for the
                    # completion-time renormalization
                    self.prediction_queue.put(Message(
                        s, w.model_idx, None, rid=req.rid))
        # outside the lock: fail() -> on_complete re-acquires _submit_lock
        for rid in exhausted:
            self.accumulator.fail(rid, RetriesExhausted(
                f"request {rid} lost workers more than retry_budget="
                f"{budget} times"))
        if member_down is not None and self.controller is not None:
            self.controller.note_member_down(*member_down)

    def demote_request(self, rid: int, keep_members) -> bool:
        """Demote in-flight request ``rid`` to the members in
        ``keep_members`` (brownout, DESIGN.md §11): members outside the set
        are added to ``Request.demoted`` and every stage *forgives* their
        remaining units — the batcher never packs them, the predictor never
        dispatches fully-demoted chunks, and the sender discards staged
        rows behind the in-flight-ledger pop-gate — so the request
        completes with a renormalized partial-ensemble answer instead of
        waiting out the heavy backlog.  Marking is GIL-atomic ``set.add``
        (advisory: a unit that raced past a stage's check is simply served;
        accounting closes either way).  Refuses 'pallas' requests (the
        fused combine needs every member) and never demotes a request's
        last remaining member.  Returns True when at least one member was
        demoted."""
        with self.accumulator._lock:
            handle = self.accumulator._requests.get(rid)
        if handle is None:
            return False                  # already completed/failed
        req = handle.req
        if req.combine == "pallas":
            return False
        keep = set(keep_members)
        kept = [m for m in req.members
                if m in keep and m not in req.demoted]
        drop = [m for m in req.members
                if m not in keep and m not in req.demoted]
        if not kept or not drop:
            return False
        for m in drop:
            req.demoted.add(m)
        self.timers.inc("requests_demoted")
        self.timers.inc("members_demoted", len(drop))
        if self.tracer.enabled:
            self.tracer.instant("admission", "demote", rid=rid,
                                args={"drop": sorted(drop),
                                      "kept": sorted(kept)})
        return True

    def retry_after_s(self) -> float:
        """Drain-estimate-derived retry hint shared by the 429 and 503
        responses (DESIGN.md §11): roughly how long until the deepest
        worker backlog clears, never a hardcoded constant."""
        if self.brownout is not None:
            return self.brownout.drain_estimate_s()
        from repro.serving.control.overload import estimate_drain_s
        return estimate_drain_s(self, self._profiler)

    def set_profiler(self, profiler) -> None:
        """Attach a live-bench sink (``observe``/``note_request``); workers
        report per-batch latency and the broadcaster reports per-member
        demand to it (DESIGN.md §8)."""
        with self._submit_lock:
            self._profiler = profiler
            for w in self.workers:
                w.profiler = profiler

    def instances(self, m: int) -> List[Worker]:
        """Snapshot of member ``m``'s live data-parallel instances."""
        with self._submit_lock:
            return list(self._instances[m])

    # ---- per-request input buffers (versioned swap) --------------------------
    def _take_buffer(self, n: int, width: int) -> np.ndarray:
        """Best-fit reuse: the smallest pooled buffer that holds ``n`` rows.
        First-fit would let one huge early request pin oversized buffers on
        every later small request for the rest of the session."""
        with self._pool_lock:
            best = -1
            for i, b in enumerate(self._buffer_pool):
                if b.shape[0] >= n and b.shape[1] == width and (
                        best < 0 or
                        b.shape[0] < self._buffer_pool[best].shape[0]):
                    best = i
            if best >= 0:
                return self._buffer_pool.pop(best)
        return np.zeros((max(n, self.segment_size), width), np.int32)

    def _on_request_complete(self, handle: RequestHandle) -> None:
        # under the topology lock: spawn_instance may add combiners
        # concurrently, and a steal's unexpect/expect_one pair (which holds
        # this lock) must not interleave with the teardown — finish() racing
        # between the two would let expect_one resurrect state for a dead
        # request that nothing ever cleans up again
        with self._submit_lock:
            for c in self.combiners.values():
                c.finish(handle.req.rid)
        with self._pool_lock:
            # a cancelled/expired request's buffer may still be read by a
            # batcher that hasn't popped its descriptors yet — never hand it
            # to a later request (the versioned-buffer guarantee, §3).  The
            # same holds after a quarantine (retries > 0 / degraded rows): a
            # stalled-but-alive quarantined worker may still read the buffer
            # whenever its threads wake up
            if handle.error is None and handle.req.retries == 0 and \
                    handle.degraded_rows == 0 and \
                    not handle.keep_buffer and \
                    len(self._buffer_pool) <= self.max_in_flight:
                self._buffer_pool.append(handle.req.x)
        charge = handle.req.budget_charge
        if charge is not None:
            handle.req.budget_charge = None
            self._credit_admission(charge)
        self._inflight.release()

    def _request_weights(self, members: List[int],
                         combine: str) -> Dict[int, float]:
        """Per-member combine weights, normalized over the active subset
        (paper §I.B "ensemble selection")."""
        if combine == "vote":
            return {m: 1.0 / len(members) for m in members}
        base = self.accumulator.weights
        wsum = float(base[members].sum())
        return {m: float(base[m]) / max(wsum, 1e-12) for m in members}

    # ---- the segment ids broadcaster -----------------------------------------
    def _broadcast(self, X: np.ndarray, members=None,
                   options: Optional[PredictOptions] = None, *,
                   plan: bool = True) -> RequestHandle:
        opts = options or PredictOptions()
        n, width = X.shape
        if members is None:
            members = opts.members
        members = list(range(self.M)) if members is None else list(members)
        if any(m < 0 or m >= self.M for m in members):
            raise ValueError(f"member ids out of range: {members}")
        if opts.member_dtype is not None:
            # precision floor (DESIGN.md §14): keep members executing at the
            # requested precision or better (fp32 > bf16 > int8/fp8)
            from repro.kernels.quant import meets_precision
            eligible = [m for m in members
                        if meets_precision(self.member_dtypes[m],
                                           opts.member_dtype)]
            if not eligible:
                raise MemberUnavailable(
                    f"no requested member executes at precision "
                    f">= {opts.member_dtype!r} "
                    f"(dtypes: {[self.member_dtypes[m] for m in members]})")
            members = eligible
        combine = opts.combine or self.combine
        if combine not in _COMBINE_RULES:
            raise ValueError(f"unknown combine rule {combine!r}")
        rec = self.trace_recorder
        if rec is not None and plan and n > 0 and members:
            # record the *offered* request — before brownout tier planning
            # or admission control can trim it — so a replayed trace
            # regenerates the original demand (DESIGN.md §12)
            rec.record(n, priority=opts.priority,
                       deadline_ms=opts.deadline_ms, members=members)
        if n == 0 or not members:
            # zero-work request: resolve immediately instead of taking an
            # in-flight slot and completing synchronously inside _submit —
            # begin()'s remaining==0 fast path would fire on_complete while
            # the submit lock is held (self-deadlock on the topology lock)
            return self._resolved_handle(X, n, members, combine)
        # overload layer (DESIGN.md §11): tier planning + cost-aware
        # admission.  At brownout level 0 (and with no controller/budget
        # attached) every branch below is a no-op, so zero-pressure results
        # stay bit-identical to the pre-brownout engine.  ``plan=False`` is
        # the cascade-escalation path: it must reach the heavy members the
        # tier just dropped.
        tier_quality = 1.0
        escalate: List[int] = []
        ctl = self.brownout
        if ctl is not None and plan:
            requested = members
            members, tier_quality = ctl.plan_members(members, opts)
            if tier_quality < 1.0 and ctl.cascade_margin is not None:
                escalate = [m for m in requested if m not in members]
            ctl.check_admission(n, members, opts)  # may raise Overloaded
        charge = None
        if self.admission_budget is not None:
            nbytes, rows = n * width * 4, n * len(members)
            if not self.admission_budget.try_charge(nbytes, rows):
                self.timers.inc("admission_rejections")
                raise Overloaded(
                    "admission byte/row budget exhausted",
                    retry_after_s=round(self.retry_after_s(), 3))
            charge = (nbytes, rows)
        deadline = opts.deadline_at()     # fixed at admission
        remaining = None if deadline is None \
            else deadline - time.perf_counter()
        # bounded in-flight window; a deadline bounds the wait for a slot,
        # and an already-expired request fails fast without enqueuing work
        if remaining is not None and (
                remaining <= 0 or
                not self._inflight.acquire(timeout=remaining)):
            self._credit_admission(charge)
            return self._resolved_handle(X, 0, members, combine,
                                         DeadlineExceeded(
                                             "deadline expired at admission"))
        if remaining is None:
            self._inflight.acquire()
        try:
            handle = self._submit(X, n, width, members, combine, opts,
                                  deadline, tier_quality=tier_quality,
                                  charge=charge,
                                  keep_buffer=bool(escalate))
        except BaseException:
            self._inflight.release()      # a failed submit must not leak a slot
            self._credit_admission(charge)   # the request never went live
            raise
        if escalate:
            from repro.serving.control.overload import CascadeHandle
            return CascadeHandle(self, handle, escalate,
                                 ctl.cascade_margin, opts)
        return handle

    def _credit_admission(self, charge) -> None:
        if charge is not None and self.admission_budget is not None:
            self.admission_budget.credit(*charge)

    def _resolved_handle(self, X, n: int, members, combine,
                         error: Optional[BaseException] = None
                         ) -> RequestHandle:
        """A pre-resolved handle that never entered the pipeline: the
        fail-fast path (``error`` set, built with n=0 so no result matrix
        is allocated just to raise) and the zero-work path (no rows or no
        members — ``Y`` stays the (n, classes) zero matrix)."""
        req = Request(-1, X, n, self.num_classes, self.segment_size,
                      list(members), {}, combine)
        handle = RequestHandle(req)
        handle.error = error
        handle._finished = True
        handle.done.set()
        return handle

    def _submit(self, X: np.ndarray, n: int, width: int,
                members: List[int], combine: str, opts: PredictOptions,
                deadline: Optional[float], *, tier_quality: float = 1.0,
                charge=None, keep_buffer: bool = False) -> RequestHandle:
        with self._submit_lock:
            if self._shutdown:
                # the unsynchronized predict_async check can race shutdown()
                # while we block on the in-flight window; descriptors
                # enqueued now would land behind SHUTDOWN and be discarded
                # (the handle would hang until the client timeout)
                raise RuntimeError("system is shut down")
            dead = [m for m in members if not self._instances[m]]
            if dead:
                # a quarantined member with no respawn yet: fail fast with
                # the retryable taxonomy (HTTP 503 + Retry-After) instead
                # of dividing by zero in the striping below.  Checked
                # before begin() so nothing registers in the accumulator.
                raise MemberUnavailable(
                    f"members {dead} have no live instance "
                    f"(quarantined; respawn pending)")
            if self._profiler is not None:    # live per-member demand (§8)
                self._profiler.note_request(members, n)
            rid = self._next_rid
            self._next_rid += 1
            buf = self._take_buffer(n, width)
            buf[:n] = X
            req = Request(rid, buf, n, self.num_classes, self.segment_size,
                          members, self._request_weights(members, combine),
                          combine, priority=opts.level(), deadline=deadline,
                          t_submit=time.perf_counter())
            handle = self.accumulator.begin(req, on_segment=opts.on_segment)
            if tier_quality < 1.0:
                # brownout tier (DESIGN.md §11): the request was planned
                # against a member subset — stamp the served weight
                # fraction; mid-flight degradation multiplies onto it
                handle.quality = tier_quality
            handle.keep_buffer = keep_buffer
            # static striping: (s, m) -> one instance; makes per-device
            # contribution counts deterministic for the partial combine.
            # Rotating by rid spreads single-segment (small) requests across
            # data-parallel instances instead of pinning them all to s=0's
            # instance; the combiner's expected map derives from this same
            # plan, so flush accounting still closes.
            plan = []
            for s in range(req.num_segments()):
                for m in members:
                    inst = self._instances[m]
                    plan.append((inst[(s + rid) % len(inst)], s))
            if self.combiners:
                expected: Dict[int, list] = {}
                for w, s in plan:
                    comb, exp = expected.setdefault(id(w.combiner),
                                                    [w.combiner, {}])
                    exp[s] = exp.get(s, 0) + 1
                for comb, exp in expected.values():
                    comb.begin(req, exp)
            for w, s in plan:
                w.input_queue.put((req, s), req.priority)
            # budget ownership transfers to the live request LAST (nothing
            # below here raises): from now on _on_request_complete credits
            # it back exactly once; any earlier exception leaves it unset
            # and _broadcast's except path credits instead
            req.budget_charge = charge
            if self.tracer.enabled:
                # the admission span: buffer take + striping + enqueue —
                # the root of the request's timeline (DESIGN.md §13)
                self.tracer.ring("admission").append(
                    ("X", "submit", req.t_submit,
                     time.perf_counter() - req.t_submit, rid,
                     {"priority": req.priority, "members": list(members),
                      "rows": n, "quality": tier_quality,
                      "deadline_ms": None if deadline is None else round(
                          1e3 * (deadline - req.t_submit), 1)},
                     None, None))
        return handle

    # ---- modes -----------------------------------------------------------------
    def predict_async(self, X: np.ndarray, members=None,
                      options: Optional[PredictOptions] = None) -> RequestHandle:
        """Submit a request without waiting; overlaps with other in-flight
        requests up to ``max_in_flight``.  Returns a handle with
        ``result(timeout)`` and ``cancel()``.  ``options`` carries the
        per-request intent (priority / deadline / members / combine /
        streaming — DESIGN.md §7); the ``members`` argument wins over
        ``options.members`` when both are given."""
        if self._shutdown:
            raise RuntimeError("system is shut down")
        return self._broadcast(np.asarray(X, np.int32), members, options)

    def predict(self, X: np.ndarray, timeout: float = 600.0,
                members=None,
                options: Optional[PredictOptions] = None) -> np.ndarray:
        """Deploy Mode.  ``members``: optional model-id subset (paper §I.B
        "ensemble selection" — e.g. a faster accuracy/speed trade-off)."""
        handle = self.predict_async(X, members, options)
        try:
            return handle.result(timeout)
        except MemoryError:
            self.shutdown()
            raise

    def benchmark(self, X: np.ndarray, repeats: int = 1,
                  timeout: float = 600.0):
        """Benchmark Mode: returns (Y, throughput samples/sec).  Repeats are
        issued through the in-flight window, so the pipeline stays full."""
        X = np.asarray(X, np.int32)
        Y = self.predict(X, timeout)          # warm the path once
        t0 = time.perf_counter()
        handles = [self.predict_async(X) for _ in range(repeats)]
        for h in handles:
            Y = h.result(timeout)
        dt = time.perf_counter() - t0
        return Y, repeats * X.shape[0] / dt

    def quiesce(self, wait: bool = False, timeout: float = 30.0) -> bool:
        """Force every worker's batcher to flush its partially-filled
        coalesced batch immediately instead of lingering ``max_wait_us`` —
        useful before latency-sensitive waits or a drain.

        Re-entrant: quiesce is a *flush*, not a teardown — ``predict_async``
        stays legal afterwards and further quiesce/submit cycles may repeat
        (the drain/restart loop the reconfiguration controller relies on,
        DESIGN.md §8).  With ``wait=True`` the call blocks until every live
        batcher has processed its flush AND every chunk flushed before the
        barrier has been dispatched (the :class:`FlushBarrier` rides the
        chunk dispatch queue and is acknowledged by the predictor), and
        returns whether all barriers were reached within ``timeout``.
        Sentinels are enqueued under the topology lock: a concurrent
        ``drain_instance`` removes its worker under the same lock *before*
        sending ``SHUTDOWN``, so a barrier is only ever queued ahead of a
        worker's SHUTDOWN (and the batcher's shutdown path releases any
        barrier that still slipped behind it) — quiesce cannot stall on a
        retiring worker."""
        with self._submit_lock:
            if self._shutdown:            # nothing left to flush; a barrier
                return True               # would stall on dead batchers
            workers = list(self.workers)
            if not wait:
                for w in workers:
                    w.input_queue.put(FLUSH)
                return True
            barriers = []
            for w in workers:
                b = FlushBarrier()
                w.input_queue.put(b)
                barriers.append(b)
        deadline = time.perf_counter() + timeout
        return all(b.done.wait(max(0.0, deadline - time.perf_counter()))
                   for b in barriers)

    def stage_timings(self) -> Dict[str, Dict[str, float]]:
        """Per-stage wall-clock counters (batcher wait / fill / predict /
        transfer / combine / accumulate) since construction or reset."""
        return self.timers.snapshot()

    def serving_counters(self) -> Dict[str, float]:
        """Coalescing counters (rows_valid / rows_dispatched / batches /
        spans) plus derived padding efficiency."""
        c = self.timers.counter_snapshot()
        c["padding_efficiency"] = self.timers.padding_efficiency()
        return c

    def serving_gauges(self) -> Dict[str, Dict[str, float]]:
        """Sampled gauges, keyed per worker (``queue_depth.<worker_id>``:
        that batcher's input-queue backlog at each drain) plus the rolling
        ``hp_p50_ms`` high-priority median latency and each worker's
        ``health.<worker_id>`` verdict (0=READY / 1=DEGRADED / 2=DEAD —
        quarantined workers keep their final DEAD reading)."""
        with self._submit_lock:
            workers = list(self.workers)
        for w in workers:                 # fresh verdicts for live workers
            self.timers.gauge(f"health.{w.worker_id}",
                              w.health(self.watchdog_s))
        return self.timers.gauge_snapshot()

    def latency_snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-priority-class end-to-end request latency percentiles
        ({"high"/"normal": {p50_ms, p99_ms, n}}) over a rolling window —
        the SLO view the chunk-granular preemption targets (DESIGN.md §3)."""
        return self.timers.latency_snapshot()

    def shutdown(self):
        with self._submit_lock:
            # flag + snapshot under the topology lock: a concurrent
            # quiesce(wait=True) either sees _shutdown (and skips) or its
            # barriers land ahead of our SHUTDOWNs and get acknowledged
            if self._shutdown:
                return
            self._shutdown = True
            workers = list(self.workers)
        if self.supervisor is not None:
            self.supervisor.stop()
        if self.controller is not None:
            self.controller.stop()
        if self.brownout is not None:
            self.brownout.stop()
        for w in workers:
            w.input_queue.put(SHUTDOWN)
        for w in workers:
            w.join()
        self.accumulator.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
