"""Pallas TPU kernel for the paper's combination rule (§II.C.2).

The prediction accumulator's hot loop is ``Y[start(s):end(s)] += P_m / M`` for
every worker message — a weighted segment accumulation.  On TPU we fuse the
whole segment combine into one kernel: given the stacked member predictions
``P (M, seg, C)`` and combination weights ``w (M,)`` (uniform 1/M for
averaging, arbitrary for weighted averaging), produce ``Y (seg, C)``.

Two variants share the grid/tiling:
  * ``ensemble_combine(P, w)``                -> Σ_m w_m P_m  (fresh combine)
  * ``ensemble_combine(P, w, partial=Y0)``    -> Y0 + Σ_m w_m P_m
The second is the *accumulate-into-partial* form used by the device-resident
partial combine (DESIGN.md §4): workers co-located on one device fold their
weighted predictions into a running partial on-device, so only one
device->host transfer happens per device per segment instead of M.

Tiling: grid = (seg_blocks, c_blocks, M); the member dim is innermost and
sequential, accumulating into a VMEM f32 scratch tile, so each (seg, C) output
tile is written once — the memory-bound optimum (reads M·seg·C (+seg·C for the
partial), writes seg·C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_SEG = 128
BLOCK_C = 512


def _kernel(p_ref, w_ref, y_ref, acc_ref, *, members: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += p_ref[0].astype(jnp.float32) * w_ref[0].astype(jnp.float32)

    @pl.when(mi == members - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _accum_kernel(part_ref, p_ref, w_ref, y_ref, acc_ref, *, members: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = part_ref[...].astype(jnp.float32)

    acc_ref[...] += p_ref[0].astype(jnp.float32) * w_ref[0].astype(jnp.float32)

    @pl.when(mi == members - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _quant_accum_kernel(part_ref, q_ref, s_ref, w_ref, y_ref, acc_ref, *,
                        members: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = part_ref[...].astype(jnp.float32)

    # Per-row dequant scale arrives replicated across the lane dim; slice
    # lane 0 and broadcast along lanes (the TPU-cheap direction).
    scale = s_ref[0][:, :1]
    deq = q_ref[0].astype(jnp.float32) * scale
    acc_ref[...] += deq * w_ref[0].astype(jnp.float32)

    @pl.when(mi == members - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def ensemble_combine_quant(partial: jax.Array, q: jax.Array,
                           scales: jax.Array, weights: jax.Array, *,
                           block_seg: int = BLOCK_SEG, block_c: int = BLOCK_C,
                           interpret: bool = False) -> jax.Array:
    """Fused dequant-weight-accumulate epilogue for quantized members.

    ``partial (seg, C) f32`` + Σ_m ``w_m · (q_m · s_m)`` where ``q (M, seg, C)``
    is int8/fp8 and ``scales (M, seg, 128) f32`` carries the per-row symmetric
    scale replicated across the lane dim (so the kernel never transposes).
    One pass: member predictions stream through VMEM once in their narrow
    storage dtype — dequantization, combine weighting, and accumulation into
    the device-resident partial all happen in-register per tile.
    """
    m, seg, c = q.shape
    block_seg = min(block_seg, seg)
    block_c = min(block_c, c)
    assert seg % block_seg == 0 and c % block_c == 0, (seg, c, block_seg, block_c)
    assert partial.shape == (seg, c), (partial.shape, seg, c)

    tile = pl.BlockSpec((block_seg, block_c), lambda s_, c_, m_: (s_, c_))
    in_specs = [
        tile,
        pl.BlockSpec((1, block_seg, block_c), lambda s_, c_, m_: (m_, s_, c_)),
        pl.BlockSpec((1, block_seg, 128), lambda s_, c_, m_: (m_, s_, 0)),
        pl.BlockSpec((1,), lambda s_, c_, m_: (m_,)),
    ]
    return pl.pallas_call(
        functools.partial(_quant_accum_kernel, members=m),
        grid=(seg // block_seg, c // block_c, m),
        in_specs=in_specs,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((seg, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_seg, block_c), jnp.float32)],
        interpret=interpret,
    )(partial, q, scales, weights)


def ensemble_combine(preds: jax.Array, weights: jax.Array,
                     partial: jax.Array = None, *,
                     block_seg: int = BLOCK_SEG, block_c: int = BLOCK_C,
                     interpret: bool = False) -> jax.Array:
    """preds: (M, seg, C); weights: (M,); optional partial: (seg, C).
    Returns (seg, C) weighted sum, plus ``partial`` when given."""
    m, seg, c = preds.shape
    block_seg = min(block_seg, seg)
    block_c = min(block_c, c)
    assert seg % block_seg == 0 and c % block_c == 0, (seg, c, block_seg, block_c)

    tile = pl.BlockSpec((block_seg, block_c), lambda s_, c_, m_: (s_, c_))
    in_specs = [
        pl.BlockSpec((1, block_seg, block_c), lambda s_, c_, m_: (m_, s_, c_)),
        pl.BlockSpec((1,), lambda s_, c_, m_: (m_,)),
    ]
    if partial is None:
        kernel = functools.partial(_kernel, members=m)
        operands = (preds, weights)
    else:
        assert partial.shape == (seg, c), (partial.shape, seg, c)
        kernel = functools.partial(_accum_kernel, members=m)
        in_specs = [tile] + in_specs
        operands = (partial, preds, weights)
    return pl.pallas_call(
        kernel,
        grid=(seg // block_seg, c // block_c, m),
        in_specs=in_specs,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((seg, c), preds.dtype),
        scratch_shapes=[pltpu.VMEM((block_seg, block_c), jnp.float32)],
        interpret=interpret,
    )(*operands)
