"""Segment protocol (paper §II.C.1) and the per-request descriptor.

Requests are split into fixed-size segments; only small descriptors flow
through the FIFO queues while the sample bytes live in the request's input
buffer.  Special ids: ``SHUTDOWN`` asks a worker to exit, ``FLUSH`` asks its
batcher to close any partially-filled coalesced batch immediately (quiesce);
workers emit ``Message(OOM/READY, ...)`` sentinels to the prediction
accumulator.

Hot-path extensions (DESIGN.md §3):
  * every in-flight request owns a :class:`Request` descriptor carrying a
    *versioned* input buffer — a new request never reallocates a buffer a
    worker may still be reading (the seed's ``shared_x`` swap race);
  * messages are tagged with the request id ``rid`` so multiple requests can
    be in flight at once;
  * a message with ``m is None`` is a *device partial*: the weighted sum of
    ``count`` member predictions, pre-combined on one device
    (DESIGN.md §4) — the accumulator just adds it into Y;
  * under the coalescing scheduler one compiled batch carries rows from
    *multiple* (request, segment) pairs — a :class:`Span` is one contiguous
    row-range of one segment inside one batch, and a batch's span list is
    the *scatter descriptor* the sender walks to route output rows back to
    their requests.  A segment's rows may therefore arrive split across
    several messages: ``Message.row_lo`` locates a message's rows inside the
    segment, and downstream accounting counts **rows, not messages**.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

SHUTDOWN = -1          # segment-ids-queue sentinel: worker must exit
FLUSH = -3             # segment-ids-queue sentinel: flush open coalesced batch
OOM = -1               # prediction-queue sentinel: device out of memory
READY = -2             # prediction-queue sentinel: worker initialized

DEFAULT_SEGMENT_SIZE = 128      # paper §III: fixed to 128


def num_segments(nb_samples: int, segment_size: int) -> int:
    return (nb_samples + segment_size - 1) // segment_size


def start(s: int, segment_size: int) -> int:
    return s * segment_size


def end(s: int, segment_size: int, nb_samples: int) -> int:
    return min((s + 1) * segment_size, nb_samples)


@dataclass
class Message:
    """The {s, m, P} triplet (paper §II.C.2), tagged with the request id.

    ``m is None`` (with ``s >= 0``) marks a device-partial message whose P
    already folds ``count`` weighted member predictions.  Under coalescing a
    per-member message may carry only a row-range of its segment: ``P`` then
    covers segment rows ``[row_lo, row_lo + len(P))`` and the accumulator
    debits rows, not messages.  Sentinels use P=None."""
    s: int                       # segment id (or OOM / READY)
    m: Optional[int]             # model id; None = device partial
    P: Optional[np.ndarray]      # (rows, C) prediction matrix
    rid: int = 0                 # request id
    count: int = 1               # member contributions folded into P
    row_lo: int = 0              # first segment row covered by P

    @property
    def is_sentinel(self) -> bool:
        return self.s < 0


@dataclass
class Request:
    """One in-flight predict() call.

    ``x`` is the request's own input buffer (rows ``[:n]`` valid).  Workers
    slice it zero-copy; because the buffer belongs to the request — not the
    system — growing a later request can never invalidate it mid-flight."""
    rid: int
    x: np.ndarray                       # (capacity >= n, seq) int32
    n: int                              # valid samples
    num_classes: int
    segment_size: int
    members: List[int]                  # active ensemble members
    weights: Dict[int, float]           # member -> normalized combine weight
    combine: str = "mean"

    def num_segments(self) -> int:
        return num_segments(self.n, self.segment_size)

    def bounds(self, s: int):
        return (start(s, self.segment_size),
                end(s, self.segment_size, self.n))


@dataclass
class Span:
    """One contiguous row-range of one segment inside one coalesced batch.

    The batcher emits a batch as ``(buffer, [Span, ...])``; the span list is
    the scatter descriptor: batch rows ``[batch_off, batch_off + n)`` hold
    segment rows ``[seg_off, seg_off + n)`` of segment ``s`` of ``req``."""
    req: Request
    s: int                       # segment id within req
    seg_off: int                 # first row within the segment (0-based)
    batch_off: int               # first row within the batch buffer
    n: int                       # row count
