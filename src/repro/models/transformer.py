"""Decoder assembly for all six architecture families.

The layer stack is ``repeats`` copies of the config's pattern unit; parameters
and decode caches carry a leading ``repeats`` dim and the stack is a single
``lax.scan`` over it (compile size independent of depth).

Public API:
    init_params(rng, cfg)                         -> param pytree
    forward(params, cfg, tokens, frontend=None)   -> (logits, aux_loss)
    prefill(params, cfg, tokens, max_len, ...)    -> (logits, cache)
    decode_step(params, cfg, cache, token, pos)   -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, CROSS, HYBRID, SSM, SWA, ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import embed, rms_norm, swiglu, unembed
from repro.models.moe import moe_ffn


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------
def _layer_param_shapes(cfg: ModelConfig, kind: str) -> Dict[str, Tuple[int, ...]]:
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.num_heads, cfg.num_kv_heads
    shapes: Dict[str, Tuple[int, ...]] = {"pre_norm": (d,)}
    if kind in (ATTN, SWA, CROSS, HYBRID):
        kv_src = cfg.fdim if kind == CROSS else d
        shapes.update(wq=(d, h, hd), wk=(kv_src, kv, hd), wv=(kv_src, kv, hd),
                      wo=(h, hd, d))
        if cfg.qk_norm:
            shapes.update(q_norm=(hd,), k_norm=(hd,))
    if kind in (SSM, HYBRID):
        s, di, nh = cfg.ssm, cfg.d_inner, cfg.ssm_heads
        shapes.update(in_proj=(d, 2 * di + 2 * s.d_state + nh),
                      conv_w=(s.d_conv, di + 2 * s.d_state),
                      dt_bias=(nh,), A_log=(nh,), D=(nh,),
                      norm=(di,), out_proj=(di, d))
    if cfg.moe is not None:
        m = cfg.moe
        shapes.update(mlp_norm=(d,), router=(d, m.num_experts),
                      w_gate=(m.num_experts, d, m.d_ff_expert),
                      w_up=(m.num_experts, d, m.d_ff_expert),
                      w_down=(m.num_experts, m.d_ff_expert, d))
        if m.shared_expert:
            shapes.update(ws_gate=(d, m.d_ff_shared), ws_up=(d, m.d_ff_shared),
                          ws_down=(m.d_ff_shared, d))
    elif cfg.d_ff > 0:
        shapes.update(mlp_norm=(d,), w_gate=(d, cfg.d_ff), w_up=(d, cfg.d_ff),
                      w_down=(cfg.d_ff, d))
    return shapes


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """Full parameter pytree of shapes (leaves: (shape, dtype-agnostic))."""
    vp, d = cfg.padded_vocab, cfg.d_model
    tree: Dict[str, Any] = {"embed": (vp, d), "final_norm": (d,)}
    if not cfg.tie_embeddings:
        tree["head"] = (d, vp)
    tree["layers"] = [
        {k: (cfg.repeats,) + v for k, v in _layer_param_shapes(cfg, kind).items()}
        for kind in cfg.pattern
    ]
    return tree


_INIT_SCALE = 0.02
_ZERO_INIT = ("pre_norm", "mlp_norm", "q_norm", "k_norm", "final_norm", "norm")


def init_params(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32):
    """Materialize parameters (used for reduced configs; full configs are
    lowered from ShapeDtypeStructs only)."""
    shapes = param_shapes(cfg)
    counter = [0]

    def make(path: str, shape):
        counter[0] += 1
        key = jax.random.fold_in(rng, counter[0])
        name = path.split("/")[-1]
        if name in _ZERO_INIT:
            return jnp.zeros(shape, dtype)
        if name == "dt_bias":
            # init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
            u = jax.random.uniform(key, shape, jnp.float32)
            dt = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        if name == "A_log":
            return jnp.log(jax.random.uniform(key, shape, jnp.float32,
                                              minval=1.0, maxval=16.0)).astype(dtype)
        if name == "D":
            return jnp.ones(shape, dtype)
        return (jax.random.normal(key, shape, jnp.float32) * _INIT_SCALE).astype(dtype)

    def build(prefix, node):
        if isinstance(node, dict):
            return {k: build(f"{prefix}/{k}", v) for k, v in node.items()}
        if isinstance(node, list):
            return [build(f"{prefix}/{i}", v) for i, v in enumerate(node)]
        return make(prefix, node)

    return build("", shapes)


def param_struct(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    def build(node):
        if isinstance(node, dict):
            return {k: build(v) for k, v in node.items()}
        if isinstance(node, list):
            return [build(v) for v in node]
        return jax.ShapeDtypeStruct(node, dtype)
    return build(param_shapes(cfg))


# --------------------------------------------------------------------------
# Full-sequence forward (training / benchmark-mode serving)
# --------------------------------------------------------------------------
def _apply_mlp(cfg: ModelConfig, lp, x):
    if cfg.moe is not None:
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        out, aux = moe_ffn(cfg, lp, h)
        return x + out, aux
    if cfg.d_ff > 0:
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        return x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), 0.0
    return x, 0.0


def _seq_constraint(x):
    """§Perf variant "seq_par": keep full-sequence activations sequence-sharded
    over the "model" axis between layers (Megatron-SP).  GSPMD then lowers the
    TP boundary as reduce-scatter + all-gather instead of full all-reduce."""
    from repro import runtime_flags
    mesh = runtime_flags.SHARDING_OPTS.get("seq_parallel")
    if mesh is None or x.ndim != 3 or x.shape[1] % mesh.shape["model"] != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.mesh import batch_axes
    bax = batch_axes(mesh)
    bax = bax if len(bax) > 1 else (bax[0] if bax else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(bax, "model", None)))


def _apply_layer(cfg: ModelConfig, kind: str, lp, x, positions, frontend,
                 use_kernel: bool):
    aux = 0.0
    x = _seq_constraint(x)
    h = rms_norm(x, lp["pre_norm"], cfg.norm_eps)
    if kind == ATTN:
        x = x + attn_mod.self_attention(cfg, lp, h, positions, window=0,
                                        use_kernel=use_kernel)
    elif kind == SWA:
        x = x + attn_mod.self_attention(cfg, lp, h, positions,
                                        window=cfg.sliding_window,
                                        use_kernel=use_kernel)
    elif kind == CROSS:
        x = x + attn_mod.cross_attention(cfg, lp, h, frontend,
                                         use_kernel=use_kernel)
    elif kind == SSM:
        x = x + ssm_mod.ssm_mixer(cfg, lp, h, use_kernel=use_kernel)
    elif kind == HYBRID:
        a = attn_mod.self_attention(cfg, lp, h, positions,
                                    window=cfg.sliding_window,
                                    use_kernel=use_kernel)
        m = ssm_mod.ssm_mixer(cfg, lp, h, use_kernel=use_kernel)
        x = x + 0.5 * (a + m)
    else:
        raise ValueError(kind)
    x, aux2 = _apply_mlp(cfg, lp, x)
    return x, aux + aux2


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            frontend: Optional[jax.Array] = None, *, use_kernel: bool = False,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B,S) int32 -> (logits (B,S,Vpad), aux_loss)."""
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.arange(tokens.shape[1])

    def unit_body(carry, unit_params):
        x, aux = carry
        for i, kind in enumerate(cfg.pattern):
            x, a = _apply_layer(cfg, kind, unit_params[i], x, positions,
                                frontend, use_kernel)
            aux = aux + a
        return (x, aux), None

    from repro import runtime_flags
    if remat:
        policy = None
        if runtime_flags.SHARDING_OPTS.get("remat_policy") == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(unit_body, policy=policy)
    else:
        body = unit_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                               unroll=runtime_flags.scan_unroll())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"] if cfg.tie_embeddings else params["head"],
                     cfg.tie_embeddings)
    return logits, aux


# --------------------------------------------------------------------------
# Prefill: forward + cache materialization
# --------------------------------------------------------------------------
def _ring_fill(k: jax.Array, L: int) -> jax.Array:
    """Place the last min(S,L) timesteps of k (B,S,...) into an L-slot ring."""
    b, s = k.shape[0], k.shape[1]
    take = min(s, L)
    tail = k[:, s - take:]
    slots = (jnp.arange(take) + (s - take)) % L
    buf = jnp.zeros((b, L) + k.shape[2:], k.dtype)
    return buf.at[:, slots].set(tail)


def _prefill_layer(cfg: ModelConfig, kind: str, lp, x, positions, frontend,
                   max_len: int, use_kernel: bool,
                   quantize_cache: bool = False):
    """Returns (x_out, cache_entry)."""
    h = rms_norm(x, lp["pre_norm"], cfg.norm_eps)
    entry: Dict[str, jax.Array] = {}
    if kind in (ATTN, SWA, HYBRID):
        window = 0 if kind == ATTN else cfg.sliding_window
        q, k, v = attn_mod.project_qkv(cfg, lp, h)
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        s = x.shape[1]
        if s <= attn_mod._DENSE_MAX:
            out = attn_mod.dense_attention(q, k, v, positions, positions,
                                           causal=True, window=window)
        else:
            out = attn_mod.chunked_attention(q, k, v, positions, positions,
                                             causal=True, window=window)
        a_out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        L = max_len if kind == ATTN else min(max_len, cfg.sliding_window)
        if kind == ATTN:
            pad = max_len - k.shape[1]
            entry["k"] = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            entry["v"] = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            entry["k"], entry["v"] = _ring_fill(k, L), _ring_fill(v, L)
        if quantize_cache:
            from repro.models.cache import quantize_kv
            entry["k"], entry["k_scale"] = quantize_kv(entry["k"])
            entry["v"], entry["v_scale"] = quantize_kv(entry["v"])
    if kind == CROSS:
        q, k, v = attn_mod.project_qkv(cfg, lp, h, kv_src=frontend)
        qp, kp = positions, jnp.arange(frontend.shape[1])
        out = attn_mod.dense_attention(q, k, v, qp, kp, causal=False) \
            if max(x.shape[1], frontend.shape[1]) <= attn_mod._DENSE_MAX else \
            attn_mod.chunked_attention(q, k, v, qp, kp, causal=False)
        a_out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        entry["k"], entry["v"] = k, v
        if quantize_cache:
            from repro.models.cache import quantize_kv
            entry["k"], entry["k_scale"] = quantize_kv(k)
            entry["v"], entry["v_scale"] = quantize_kv(v)
    if kind in (SSM, HYBRID):
        m_out, h_state, conv_tail = ssm_mod.ssm_mixer(cfg, lp, h,
                                                      use_kernel=use_kernel,
                                                      return_state=True)
        entry["h"], entry["conv"] = h_state, conv_tail
    # combine mixer outputs
    if kind in (ATTN, SWA, CROSS):
        x = x + a_out
    elif kind == SSM:
        x = x + m_out
    elif kind == HYBRID:
        x = x + 0.5 * (a_out + m_out)
    x, _ = _apply_mlp(cfg, lp, x)
    return x, entry


def prefill(params, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            frontend: Optional[jax.Array] = None, *,
            use_kernel: bool = False,
            quantize_cache: bool = False) -> Tuple[jax.Array, Any]:
    """Run the prompt, return (last-token logits (B,Vpad), cache).

    ``quantize_cache``: store KV as int8 + per-slot scales (decode must then
    run the dequantizing path — automatic, keyed off the cache contents)."""
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.arange(tokens.shape[1])

    def unit_body(x, unit_params):
        entries = []
        for i, kind in enumerate(cfg.pattern):
            x, e = _prefill_layer(cfg, kind, unit_params[i], x, positions,
                                  frontend, max_len, use_kernel,
                                  quantize_cache)
            entries.append(e)
        return x, entries

    from repro import runtime_flags
    x, cache_layers = jax.lax.scan(unit_body, x, params["layers"],
                                   unroll=runtime_flags.scan_unroll())
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"] if cfg.tie_embeddings else params["head"],
                     cfg.tie_embeddings)
    return logits[:, 0], {"layers": cache_layers}


# --------------------------------------------------------------------------
# Decode step: one token against the cache
# --------------------------------------------------------------------------
def _decode_layer(cfg: ModelConfig, kind: str, lp, entry, x, pos,
                  use_kernel: bool):
    h = rms_norm(x, lp["pre_norm"], cfg.norm_eps)
    new_entry = dict(entry)
    if kind in (ATTN, SWA):
        window = 0 if kind == ATTN else cfg.sliding_window
        if "k_scale" in entry:       # int8-quantized cache
            (a_out, new_entry["k"], new_entry["v"], new_entry["k_scale"],
             new_entry["v_scale"]) = attn_mod.decode_attention(
                cfg, lp, h, entry["k"], entry["v"], pos, window=window,
                use_kernel=use_kernel, k_scale=entry["k_scale"],
                v_scale=entry["v_scale"])
        else:
            a_out, new_entry["k"], new_entry["v"] = attn_mod.decode_attention(
                cfg, lp, h, entry["k"], entry["v"], pos, window=window,
                use_kernel=use_kernel)
        x = x + a_out
    elif kind == CROSS:
        q, _, _ = attn_mod.project_qkv(
            cfg, lp, h, kv_src=jnp.zeros((x.shape[0], 1, cfg.fdim), x.dtype))
        kc, vc = entry["k"], entry["v"]
        if "k_scale" in entry:
            from repro.models.cache import dequantize_kv
            kc = dequantize_kv(kc, entry["k_scale"], h.dtype)
            vc = dequantize_kv(vc, entry["v_scale"], h.dtype)
        out = attn_mod.dense_attention(
            q, kc, vc, jnp.arange(1),
            jnp.arange(kc.shape[1]), causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
    elif kind == SSM:
        m_out, new_entry["h"], new_entry["conv"] = ssm_mod.ssm_decode_step(
            cfg, lp, h, entry["h"], entry["conv"])
        x = x + m_out
    elif kind == HYBRID:
        if "k_scale" in entry:       # int8-quantized cache
            (a_out, new_entry["k"], new_entry["v"], new_entry["k_scale"],
             new_entry["v_scale"]) = attn_mod.decode_attention(
                cfg, lp, h, entry["k"], entry["v"], pos,
                window=cfg.sliding_window, use_kernel=use_kernel,
                k_scale=entry["k_scale"], v_scale=entry["v_scale"])
        else:
            a_out, new_entry["k"], new_entry["v"] = attn_mod.decode_attention(
                cfg, lp, h, entry["k"], entry["v"], pos,
                window=cfg.sliding_window, use_kernel=use_kernel)
        m_out, new_entry["h"], new_entry["conv"] = ssm_mod.ssm_decode_step(
            cfg, lp, h, entry["h"], entry["conv"])
        x = x + 0.5 * (a_out + m_out)
    x, _ = _apply_mlp(cfg, lp, x)
    return x, new_entry


def decode_step(params, cfg: ModelConfig, cache, token: jax.Array, pos,
                *, use_kernel: bool = False) -> Tuple[jax.Array, Any]:
    """token: (B,1) int32, pos: scalar int32 -> (logits (B,Vpad), new cache)."""
    x = embed(token, params["embed"], cfg.embed_scale)

    def unit_body(x, xs):
        unit_params, unit_cache = xs
        new_entries = []
        for i, kind in enumerate(cfg.pattern):
            x, e = _decode_layer(cfg, kind, unit_params[i], unit_cache[i], x,
                                 pos, use_kernel)
            new_entries.append(e)
        return x, new_entries

    from repro import runtime_flags
    x, new_layers = jax.lax.scan(unit_body, x, (params["layers"], cache["layers"]),
                                 unroll=runtime_flags.scan_unroll())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"] if cfg.tie_embeddings else params["head"],
                     cfg.tie_embeddings)
    return logits[:, 0], {"layers": new_layers}


# --------------------------------------------------------------------------
# Convenience object used by serving / examples
# --------------------------------------------------------------------------
class Model:
    """Thin functional wrapper binding a config to the apply functions."""

    def __init__(self, cfg: ModelConfig, use_kernel: bool = False):
        self.cfg = cfg
        self.use_kernel = use_kernel

    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.cfg, dtype)

    def __call__(self, params, tokens, frontend=None):
        return forward(params, self.cfg, tokens, frontend,
                       use_kernel=self.use_kernel)

    def forward_fn(self):
        return functools.partial(forward, cfg=self.cfg, use_kernel=self.use_kernel)
