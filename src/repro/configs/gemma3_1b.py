"""gemma3-1b [dense] — 5:1 local:global attention, MQA (kv=1), 262k vocab,
head_dim decoupled from d_model.  [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ATTN, SWA, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,           # 26 = 2 units... pattern unit is 13? use 5:1 pattern below
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    # gemma3: five local (window) layers for every global layer
    # 26 layers = 4 full units of 6 + 2 extra locals folded as one 13-layer unit x2
    pattern=(SWA, SWA, SWA, SWA, SWA, ATTN, SWA, SWA, SWA, SWA, SWA, ATTN, SWA),
    sliding_window=512,
    rope_theta=1_000_000.0,
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
