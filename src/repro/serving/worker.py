"""A worker: one model instance pinned to one device at one batch size.

Faithful to paper Fig. 2 — three asynchronous threads per worker:
  * the *batcher* turns incoming segment ids into padded batches,
  * the *predictor* owns the params on its device and runs the jitted step,
  * the *prediction sender* reassembles batch outputs into segment
    predictions and forwards them (device partial or {s, m, P} message).

Hardware adaptation (DESIGN.md §2): the paper uses one OS process per worker
(TF1 sessions hold the GIL); with JAX, XLA executions release the GIL and
dispatch is asynchronous, so threads + per-worker queues give the same
overlap without IPC serialization overhead.

Hot-path mechanics (DESIGN.md §3):
  * the batcher writes each segment into a **preallocated ring** of
    segment-span slots with one vectorized fill — batches are offset views
    into the slot, so there is no per-chunk allocation or
    ``np.concatenate``-padding; slot backpressure (a free-list queue) bounds
    in-flight memory, and a slot is recycled only after the predictor's
    output is materialized — on CPU ``device_put`` may alias host memory, so
    early reuse would corrupt an in-flight batch;
  * short remainder chunks are padded to the next **power-of-two bucket**
    (not the full compiled batch) — one jitted callable serves every bucket,
    with jit's shape cache bounding compilations to ~log2(batch) entries, and
    input buffers are donated on accelerators so XLA can reuse them;
  * per-stage wall-clock counters (metrics.StageTimers) instrument the
    batcher wait, batch fill, predict dispatch, and device sync/transfer.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.devices import DeviceSpec
from repro.kernels.ops import pow2_clamp
from repro.serving import segments as seg
from repro.serving.metrics import StageTimers
from repro.serving.segments import Message, Request, SHUTDOWN

MIN_BUCKET = 8


def bucket_for(n: int, batch_size: int) -> int:
    """Compiled batch shape for an ``n``-row chunk: the full batch size, or
    the next power of two >= n (min 8) for remainder chunks."""
    if n >= batch_size:
        return batch_size
    return pow2_clamp(n, MIN_BUCKET, batch_size)


def make_predict_fn(cfg: ModelConfig, use_kernel: bool = False,
                    donate: bool = False) -> Callable:
    """Classification-style serving fn: tokens (b,S) -> last-token class
    scores (b, C) with C = the unpadded vocab (the paper's f(x)->y).
    ``donate`` hands the token buffer to XLA for reuse (accelerators only —
    CPU ignores donation and would warn on every compile)."""
    from repro.models import forward

    def predict(params, tokens, frontend):
        logits, _ = forward(params, cfg, tokens, frontend, use_kernel=use_kernel)
        return logits[:, -1, :cfg.vocab_size]

    return jax.jit(predict, donate_argnums=(1,) if donate else ())


class Worker:
    def __init__(self, worker_id: str, cfg: ModelConfig, params,
                 device: DeviceSpec, batch_size: int,
                 input_queue: "queue.Queue",
                 prediction_queue: "queue.Queue[Message]",
                 model_idx: int, max_seq: int, segment_size: int,
                 *, fake: bool = False, frontend: Optional[np.ndarray] = None,
                 use_kernel: bool = False, combiner=None,
                 timers: Optional[StageTimers] = None):
        self.worker_id = worker_id
        self.cfg = cfg
        self.batch_size = batch_size
        self.model_idx = model_idx
        self.input_queue = input_queue
        self.prediction_queue = prediction_queue
        self.segment_size = segment_size
        self.fake = fake
        self.device = device
        self.combiner = combiner
        self.timers = timers or StageTimers()
        self.num_classes = cfg.vocab_size
        self._batch_q: "queue.Queue" = queue.Queue(maxsize=4)
        self._send_q: "queue.Queue" = queue.Queue(maxsize=8)
        self._threads: List[threading.Thread] = []
        self._jax_device = device.jax_devices[0] if device.jax_devices else None

        # preallocated input ring: one segment-span slot per entry (chunks are
        # offset views into the slot), 4 deep so later segments batch while
        # earlier ones predict
        chunks_per_seg = max(1, -(-segment_size // batch_size))
        self._span = chunks_per_seg * batch_size
        self._ring = [np.zeros((self._span, max_seq), np.int32)
                      for _ in range(4)]
        self._free_slots: "queue.Queue[int]" = queue.Queue()
        for i in range(len(self._ring)):
            self._free_slots.put(i)

        try:
            if self._jax_device is not None:
                params = jax.device_put(params, self._jax_device)
            self.params = params
            self.frontend = None
            if cfg.frontend_tokens:
                fe = frontend if frontend is not None else np.zeros(
                    (batch_size, cfg.frontend_tokens, cfg.fdim), np.float32)
                self.frontend = jnp.asarray(fe)
            donate = jax.default_backend() in ("gpu", "tpu")
            self.predict_fn = make_predict_fn(cfg, use_kernel, donate=donate)
            if not fake:   # warm-up compile so READY means actually servable
                warm = jnp.zeros((batch_size, max_seq), jnp.int32)
                np.asarray(self.predict_fn(self.params, warm, self.frontend))
            self.prediction_queue.put(Message(seg.READY, model_idx, None))
        except (MemoryError, RuntimeError, ValueError):
            # paper §II.C.2: {-1, None, None} triggers system shutdown
            self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    # ---- threads -------------------------------------------------------------
    def start(self):
        for fn, name in [(self._batcher, "batcher"), (self._predictor, "predictor"),
                         (self._sender, "sender")]:
            t = threading.Thread(target=self._guarded, args=(fn,),
                                 name=f"{self.worker_id}-{name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _guarded(self, fn):
        """A stage thread dying mid-request would hang its request (and leak
        its in-flight window slot) forever — convert runtime failures into
        the paper's {-1, None, None} sentinel, which fails every in-flight
        request and shuts the system down."""
        try:
            fn()
        except BaseException:
            self.prediction_queue.put(Message(seg.OOM, None, None))
            raise

    def join(self, timeout: float = 30.0):
        for t in self._threads:
            t.join(timeout)

    # ---- stage 1: batcher ----------------------------------------------------
    def _batcher(self):
        while True:
            t0 = time.perf_counter()
            item = self.input_queue.get()
            t0 = self.timers.timed("batcher_wait", t0)
            if item == SHUTDOWN:
                self._batch_q.put(None)
                return
            req, s = item                     # type: Request, int
            lo, hi = req.bounds(s)
            data = req.x[lo:hi]               # zero-copy view of the request
            n = hi - lo
            if data.shape[1] == self._ring[0].shape[1]:
                slot = self._free_slots.get()
                buf = self._ring[slot]
            else:                  # rare: request seq != compiled ring seq
                slot, buf = None, np.zeros((self._span, data.shape[1]),
                                           np.int32)
            buf[:n] = data                    # one vectorized fill per segment
            chunks = []                       # (offset, bucket, valid) views
            for i in range(0, n, self.batch_size):
                valid = min(self.batch_size, n - i)
                bucket = bucket_for(valid, self.batch_size)
                if valid < bucket:
                    buf[i + valid:i + bucket] = 0     # stale tail rows
                chunks.append((i, bucket, valid))
            self._batch_q.put((req, s, slot, buf, chunks))
            self.timers.timed("batch_fill", t0)

    # ---- stage 2: predictor --------------------------------------------------
    def _predictor(self):
        while True:
            item = self._batch_q.get()
            if item is None:
                self._send_q.put(None)
                return
            req, s, slot, buf, chunks = item
            t0 = time.perf_counter()
            outs = None
            if not self.fake:
                outs = []
                for off, bucket, valid in chunks:
                    view = buf[off:off + bucket]
                    if self._jax_device is not None:
                        x = jax.device_put(view, self._jax_device)
                    else:
                        x = jnp.asarray(view)
                    fe = (self.frontend[:bucket]
                          if self.frontend is not None else None)
                    y = self.predict_fn(self.params, x, fe)
                    outs.append((valid, y))    # async dispatch: no block here
            self._send_q.put((req, s, slot, outs))
            self.timers.timed("predict", t0)

    # ---- stage 3: sender -----------------------------------------------------
    def _sender(self):
        on_device = self.combiner is not None
        while True:
            item = self._send_q.get()
            if item is None:
                return
            req, s, slot, outs = item
            t0 = time.perf_counter()
            lo, hi = req.bounds(s)
            if outs is None:                   # fake predictor: instant zeros
                P = np.zeros((hi - lo, self.num_classes), np.float32)
            else:
                parts = []
                for valid, y in outs:
                    if on_device:
                        y.block_until_ready()  # compute done; stays on device
                        parts.append(y[:valid])
                    else:
                        parts.append(np.asarray(y)[:valid])  # d->h sync
                if len(parts) == 1:
                    P = parts[0]
                elif on_device:
                    P = jnp.concatenate(parts, axis=0)
                else:
                    P = np.concatenate(parts, axis=0)
                assert P.shape[0] == hi - lo
            if slot is not None:               # ring slot safe to recycle now
                self._free_slots.put(slot)
            self.timers.timed("transfer", t0)
            if on_device:
                self.combiner.add(req, s, self.model_idx, P)
            else:
                self.prediction_queue.put(Message(s, self.model_idx,
                                                  np.asarray(P), rid=req.rid))
