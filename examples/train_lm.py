"""Training driver (deliverable b): train a reduced assigned-pool LM for a
few hundred steps on the synthetic bigram task, with checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b \
          --steps 300 --d-model 256 [--resume]
The default reduced model is ~1.3M params; pass --d-model 512 --layers 8 for
a bigger run (~100M-class configs need the TPU pod — see launch/train.py).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

import repro.models as M
from repro.configs import get_config
from repro.data.pipeline import PrefetchIterator, SyntheticLM
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(layers=args.layers,
                                        d_model=args.d_model)
    print(f"training {cfg.name}: {cfg.param_count():,} params, "
          f"{cfg.num_layers}L d={cfg.d_model}")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    start_step = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        params = ckpt.restore(args.ckpt_dir, params)
        start_step = ckpt.latest_step(args.ckpt_dir)
        print(f"resumed from step {start_step}")

    data = PrefetchIterator(
        SyntheticLM(cfg.vocab_size, args.seq, task="ngram").iterator(
            args.batch, cfg))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=20,
                           total_steps=args.steps)

    def log(m):
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
              f"({m['elapsed_s']:.0f}s)")

    params, hist = train(cfg, params, data, ocfg, steps=args.steps,
                         log_every=20, callback=log)
    path = ckpt.save(args.ckpt_dir, start_step + args.steps, params)
    print(f"final loss {hist[-1]['loss']:.4f}; checkpoint -> {path}")
    data.close()


if __name__ == "__main__":
    main()
