"""Overload robustness: brownout levels, cost-aware admission, cascades.

PR 6 made the system survive *failures*; this module makes it survive
*success* — sustained offered load beyond capacity (DESIGN.md §11).  The
paper's pipeline degrades by the request under overload: queues grow,
deadlines blow out, callers get 504s.  Here the system degrades by
*quality* instead (ROADMAP direction 2, grounded in *Flexible DNN
Processing*'s incremental-quality inference and *EARN*'s accuracy/cost
Pareto tiers):

* :class:`BrownoutController` folds existing telemetry — admission/dispatch
  queue depths, ``latency_snapshot()`` p99 against a deadline budget,
  deadline-miss and dropped-row rates — into one continuous **pressure**
  signal, and maps it through hysteresis (asymmetric up/down dwell, so the
  level cannot flap at a threshold) to a discrete **brownout level**;

* each level selects a member-subset **quality tier** from a tier table
  ordered by cost-per-unit-weight (level 0 = the full ensemble; each deeper
  tier drops the most expensive remaining member per unit of combine
  weight, with per-member costs taken from the :class:`LiveBench` latency
  EWMA when warm).  New normal-priority requests are *planned* against the
  active tier's subset — reusing the ``PredictOptions.members`` path and
  the missing-weight renormalization from PR 6 — and their handles carry
  the tier's quality;

* on a level-up, already-admitted requests are **demoted mid-flight**:
  dropped members are added to ``Request.demoted`` and every stage forgives
  (never DROPPED-fails) that member's remaining units — the batcher skips
  packing, the predictor skips dispatching fully-demoted chunks, and the
  sender discards staged rows behind the same in-flight-ledger pop-gate
  that makes quarantine replay idempotent.  The backlog drains at the
  cheap tier instead of timing out;

* admission gains a **feasibility check** (estimated drain + service time
  vs the request's ``deadline_ms``) that fails fast with
  :class:`~repro.serving.segments.Overloaded` — surfaced as HTTP 429 with
  a ``Retry-After`` computed from :func:`estimate_drain_s`, not a
  hardcoded constant;

* an optional **confidence-gated cascade** (:class:`CascadeHandle`)
  escalates an individual request back to the heavier members only when
  the cheap tier's combined output is uncertain (small top1-top2 margin),
  bounding the accuracy loss of serving the cheap tier by default.

Level 0 is a strict no-op on the hot path: ``plan_members`` returns the
caller's member list untouched, so zero-pressure results stay bit-identical
to the pre-brownout engine.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.quant import dtype_bytes as _dtype_bytes
from repro.serving.segments import (Overloaded, PredictOptions,
                                    PRIORITY_HIGH, Request)

# fallback per-segment service time used in drain estimates before any
# latency has been measured (fake workers fold their simulated delay in)
DEFAULT_SEGMENT_S = 1e-3
RETRY_AFTER_FLOOR_S = 0.05


def build_tier_table(weights: Sequence[float],
                     costs: Sequence[float]) -> List[Tuple[int, ...]]:
    """EARN-style accuracy/cost tier table: level 0 keeps every member;
    each deeper level drops the remaining member with the worst
    cost-per-unit-combine-weight (the least accuracy bought per second of
    device time), down to the single cheapest-per-weight member.  Weights
    proxy the accuracy contribution — exactly what the combine uses."""
    members = list(range(len(costs)))
    tiers = [tuple(members)]
    cur = list(members)
    while len(cur) > 1:
        drop = max(cur, key=lambda m: costs[m] / max(float(weights[m]), 1e-12))
        cur = [m for m in cur if m != drop]
        tiers.append(tuple(cur))
    return tiers


def estimate_drain_s(system, live=None, *,
                     default_segment_s: float = DEFAULT_SEGMENT_S,
                     floor_s: float = RETRY_AFTER_FLOOR_S) -> float:
    """Estimated wall time until the deepest worker backlog drains — the
    basis for every ``Retry-After`` this layer emits (429 and 503 alike).
    Backlog is counted in segments (admission queue + dispatch queue /
    chunks-per-segment) and priced by the LiveBench per-segment EWMA when
    warm, falling back to the simulated delay (fake workers) or a flat
    default.  ``floor_s`` keeps client backoff sane; feasibility checks
    pass 0.0 so an idle system never inflates the estimate past a tight
    deadline."""
    worst = 0.0
    for w in list(system.workers):
        backlog = w.input_queue.qsize() + \
            w.dispatch_backlog() / max(1, w.chunks_per_segment)
        if backlog <= 0:
            continue
        t_seg = None
        if live is not None:
            t_seg = live.segment_time(w.model_idx, w.device.key(),
                                      w.batch_size, w.segment_size)
        if t_seg is None:
            per_chunk = max(w.fake_delay_us * 1e-6,
                            default_segment_s / max(1, w.chunks_per_segment))
            t_seg = per_chunk * w.chunks_per_segment
        worst = max(worst, backlog * t_seg)
    return max(floor_s, worst)


class BrownoutController:
    """Maps a continuous pressure signal to discrete brownout levels with
    hysteresis, and applies the active level's quality tier to admission
    and to already-in-flight requests (DESIGN.md §11).

    Pressure is the max of two normalized terms plus a loss term:

    * **queue term** — deepest per-worker backlog (admission + dispatch, in
      segments) over ``depth_ref``;
    * **latency term** — normal-class rolling p99 over
      ``deadline_budget_ms``;
    * **loss term** — 1.0 whenever deadline misses or dropped rows grew
      since the last tick (the system is already failing requests — more
      direct evidence of overload than any queue depth).

    The level steps **up** after ``up_ticks`` consecutive ticks above
    ``high`` and steps **down** only after ``down_ticks`` consecutive
    ticks below ``low`` — with ``low < high`` this is classic dual-band
    hysteresis, so a pressure signal oscillating around either threshold
    cannot flap the tier.

    ``step()`` is the whole control law and takes an optional explicit
    pressure, so tests drive it synchronously; ``start()`` runs it on a
    background thread every ``interval_s``.  Construction attaches the
    controller as ``system.brownout`` — the broadcaster consults it at
    admission."""

    def __init__(self, system, *, live=None,
                 tiers: Optional[Sequence[Sequence[int]]] = None,
                 high: float = 1.0, low: float = 0.4,
                 up_ticks: int = 2, down_ticks: int = 10,
                 interval_s: float = 0.01,
                 depth_ref: float = 16.0,
                 deadline_budget_ms: Optional[float] = None,
                 demote_inflight: bool = True,
                 cascade_margin: Optional[float] = None,
                 feasibility: bool = True):
        if low >= high:
            raise ValueError(f"hysteresis bands must satisfy low < high, "
                             f"got low={low} high={high}")
        self.system = system
        self.live = live if live is not None \
            else getattr(system, "_profiler", None)
        self.high = high
        self.low = low
        self.up_ticks = max(1, up_ticks)
        self.down_ticks = max(1, down_ticks)
        self.interval_s = interval_s
        self.depth_ref = max(1.0, depth_ref)
        self.deadline_budget_ms = deadline_budget_ms
        self.demote_inflight = demote_inflight
        self.cascade_margin = cascade_margin
        self.feasibility = feasibility
        self._tiers = ([tuple(t) for t in tiers] if tiers is not None
                       else None)              # lazily built from live costs
        self._tier_sets: Optional[List[frozenset]] = None
        self._level = 0
        self._above = 0
        self._below = 0
        self._last_loss = 0.0
        self._last_pressure = 0.0
        self.transitions = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        system.brownout = self
        system.timers.gauge("brownout_level", 0)

    # ---- tier table ----------------------------------------------------------
    def member_costs(self) -> List[float]:
        """Per-row service-time estimate per member: the cheapest live
        instance's LiveBench per-segment EWMA when warm, the simulated
        delay for fake workers, else a uniform 1.0 (an unmeasured ensemble
        tiers by combine weight alone).  Unmeasured estimates (fake delay /
        uniform fallback) are scaled by the member's param-dtype byte ratio
        (DESIGN.md §14): a memory-bandwidth-bound int8 member streams ~1/4
        the bytes, so quantized members price as the cheap tier and survive
        deepest into a brownout.  Measured EWMAs already embed the speedup
        and are never rescaled."""
        sys_ = self.system
        costs = []
        for m in range(sys_.M):
            best = None
            ratio = 1.0                    # cheapest instance's dtype ratio
            for w in sys_.instances(m):
                ratio = min(ratio, _dtype_bytes(
                    getattr(w, "member_dtype", None)) / 4.0)
                t = None
                if self.live is not None:
                    t = self.live.segment_time(m, w.device.key(),
                                               w.batch_size, w.segment_size)
                if t is None and w.fake_delay_us:
                    t = (w.fake_delay_us * 1e-6 * w.chunks_per_segment
                         * (_dtype_bytes(getattr(w, "member_dtype",
                                                 None)) / 4.0))
                if t is not None:
                    t /= max(1, w.segment_size)
                    best = t if best is None else min(best, t)
            costs.append(best if best is not None else ratio)
        return costs

    def tiers(self) -> List[Tuple[int, ...]]:
        if self._tiers is None:
            self._tiers = build_tier_table(self.system.accumulator.weights,
                                           self.member_costs())
        if self._tier_sets is None or \
                len(self._tier_sets) != len(self._tiers):
            self._tier_sets = [frozenset(t) for t in self._tiers]
        return self._tiers

    def _tier_set(self, level: int) -> frozenset:
        tiers = self.tiers()
        return self._tier_sets[min(level, len(tiers) - 1)]

    # ---- the pressure signal -------------------------------------------------
    def pressure(self) -> float:
        sys_ = self.system
        qp = 0.0
        for w in list(sys_.workers):
            backlog = w.input_queue.qsize() + \
                w.dispatch_backlog() / max(1, w.chunks_per_segment)
            qp = max(qp, backlog / self.depth_ref)
        lp = 0.0
        if self.deadline_budget_ms:
            lat = sys_.latency_snapshot().get("normal", {})
            lp = lat.get("p99_ms", 0.0) / self.deadline_budget_ms
        c = sys_.timers.counter_snapshot()
        loss = c.get("deadline_misses", 0.0) + c.get("rows_dropped", 0.0)
        loss_term = 1.0 if loss > self._last_loss else 0.0
        self._last_loss = loss
        return max(qp, lp) + loss_term

    # ---- the control law -----------------------------------------------------
    def step(self, pressure: Optional[float] = None) -> int:
        """One control tick: fold the pressure through the hysteresis bands
        and apply any level transition.  Returns the (possibly new) level."""
        p = self.pressure() if pressure is None else pressure
        self._last_pressure = p
        if p > self.high:
            self._above += 1
            self._below = 0
        elif p < self.low:
            self._below += 1
            self._above = 0
        else:                         # inside the dead band: hold the level
            self._above = 0
            self._below = 0
        max_level = len(self.tiers()) - 1
        if self._above >= self.up_ticks and self._level < max_level:
            self._above = 0
            self._transition(self._level + 1)
        elif self._below >= self.down_ticks and self._level > 0:
            self._below = 0
            self._transition(self._level - 1)
        return self._level

    @property
    def level(self) -> int:
        return self._level

    def _transition(self, new_level: int) -> None:
        old = self._level
        self._level = new_level
        self.transitions += 1
        self.system.timers.inc("brownout_transitions")
        self.system.timers.gauge("brownout_level", new_level)
        tr = getattr(self.system, "tracer", None)
        if tr is not None:
            # brownout shifts are exactly when a timeline dump is worth
            # keeping: tag the snapshot with the level change
            tr.anomaly("brownout_level_change",
                       f"level {old} -> {new_level}",
                       args={"from": old, "to": new_level})
        if new_level > old and self.demote_inflight:
            self._demote_inflight(self._tier_set(new_level))

    def _demote_inflight(self, keep: frozenset) -> None:
        """On a level-up, demote already-admitted normal-priority requests
        to the new tier so the existing backlog drains at the cheap tier
        instead of timing out at the old one."""
        acc = self.system.accumulator
        with acc._lock:
            handles = list(acc._requests.values())
        for h in handles:
            req = h.req
            if req.priority == PRIORITY_HIGH:
                continue
            self.system.demote_request(req.rid, keep)

    # ---- admission hooks (called by the broadcaster) -------------------------
    def plan_members(self, members: List[int],
                     opts: PredictOptions) -> Tuple[List[int], float]:
        """Intersect a new normal-priority request's member list with the
        active tier; returns ``(planned_members, tier_quality)`` where
        quality is the served fraction of the request's combine weight.
        Level 0 (and high priority, and the 'pallas' combine — its fused
        kernel needs every member) returns the input untouched."""
        lvl = self._level
        if lvl <= 0 or opts.level() == PRIORITY_HIGH or \
                (opts.combine or self.system.combine) == "pallas":
            return members, 1.0
        keep = self._tier_set(lvl)
        kept = [m for m in members if m in keep]
        if not kept or len(kept) == len(members):
            return members, 1.0
        base = self.system.accumulator.weights
        full = float(base[members].sum())
        q = float(base[kept].sum()) / max(full, 1e-12)
        self.system.timers.inc("brownout_planned")
        return kept, min(1.0, q)

    def service_estimate_s(self, n: int, members: Sequence[int]) -> float:
        """Estimated service time for an ``n``-row request over ``members``:
        the slowest member's per-segment time x its segment count, divided
        across its data-parallel instances (striping spreads segments)."""
        sys_ = self.system
        worst = 0.0
        for m in members:
            inst = sys_.instances(m)
            if not inst:
                continue
            segs = -(-n // sys_.segment_size)
            best = None
            for w in inst:
                t = None
                if self.live is not None:
                    t = self.live.segment_time(m, w.device.key(),
                                               w.batch_size, w.segment_size)
                if t is None:
                    per_chunk = max(w.fake_delay_us * 1e-6,
                                    DEFAULT_SEGMENT_S /
                                    max(1, w.chunks_per_segment))
                    t = per_chunk * w.chunks_per_segment
                best = t if best is None else min(best, t)
            worst = max(worst, (best or 0.0) * segs / len(inst))
        return worst

    def drain_estimate_s(self) -> float:
        return estimate_drain_s(self.system, self.live)

    def check_admission(self, n: int, members: Sequence[int],
                        opts: PredictOptions) -> None:
        """Cost-aware feasibility: a deadline the system cannot possibly
        meet at the current backlog fails *now* with
        :class:`Overloaded` (HTTP 429) instead of consuming pipeline
        resources on its way to a 504.  Deadline-less requests always pass
        (the byte/row budget is their only gate)."""
        if not self.feasibility or opts.deadline_ms is None:
            return
        # unfloored: an idle system must not inflate the estimate past a
        # tight-but-feasible deadline (level-0 no-op guarantee)
        drain = estimate_drain_s(self.system, self.live, floor_s=0.0)
        est = drain + self.service_estimate_s(n, members)
        if est > opts.deadline_ms * 1e-3:
            self.system.timers.inc("admission_rejections")
            raise Overloaded(
                f"infeasible at current pressure: estimated "
                f"{est * 1e3:.0f}ms (drain {drain * 1e3:.0f}ms) exceeds "
                f"deadline_ms={opts.deadline_ms:g}",
                retry_after_s=round(max(drain, RETRY_AFTER_FLOOR_S), 3))

    # ---- lifecycle / observability -------------------------------------------
    def start(self) -> "BrownoutController":
        self._thread = threading.Thread(target=self._run, name="brownout",
                                        daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.step()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None

    def stats(self) -> dict:
        return {"level": self._level,
                "pressure": round(self._last_pressure, 4),
                "transitions": self.transitions,
                "tiers": [list(t) for t in self.tiers()],
                "drain_estimate_s": round(self.drain_estimate_s(), 4)}


class CascadeHandle:
    """Confidence-gated cascade over a tier-planned request (DESIGN.md
    §11).  Duck-types :class:`~repro.serving.accumulator.RequestHandle`:
    ``result()`` first resolves the cheap-tier submission, and only when
    the combined output is *uncertain* (mean top1-top2 margin below the
    threshold) escalates to the members the tier dropped, merging by the
    members' combine-weight fractions — mathematically the full-ensemble
    combine, since each side is a renormalized convex partial sum.

    ``done`` reflects the tier result's readiness (best-effort: a pending
    escalation still blocks inside ``result()``)."""

    def __init__(self, system, inner, escalate: List[int],
                 margin: float, opts: PredictOptions):
        self._system = system
        self._inner = inner
        self._escalate = escalate
        self._margin = margin
        self._opts = opts
        self._resolved: Optional[np.ndarray] = None
        self._quality: Optional[float] = None
        self.req = inner.req
        self.done = inner.done

    @property
    def error(self):
        return self._inner.error

    @property
    def quality(self) -> float:
        if self._quality is not None:
            return self._quality
        return getattr(self._inner, "quality", 1.0)

    def cancel(self) -> bool:
        return self._inner.cancel()

    @staticmethod
    def _mean_margin(Y: np.ndarray) -> float:
        if Y.shape[1] < 2:
            return float("inf")
        part = np.partition(Y, Y.shape[1] - 2, axis=1)
        return float((part[:, -1] - part[:, -2]).mean())

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if self._resolved is not None:
            return self._resolved
        t0 = time.perf_counter()
        Y = self._inner.result(timeout)
        if not self._escalate or self._mean_margin(Y) >= self._margin:
            self._resolved = Y                # confident: cheap tier stands
            self._quality = getattr(self._inner, "quality", 1.0)
            return Y
        # uncertain: escalate to the dropped members, bypassing tier
        # planning (plan=False) so brownout cannot re-demote the escalation
        self._system.timers.inc("cascade_escalations")
        req = self.req
        h2 = self._system._broadcast(np.asarray(req.x[:req.n]),
                                     self._escalate, self._opts, plan=False)
        left = None if timeout is None \
            else max(0.0, timeout - (time.perf_counter() - t0))
        Y2 = h2.result(left)
        base = self._system.accumulator.weights
        kept = [m for m in req.members if m not in req.demoted]
        wk = float(base[kept].sum())
        we = float(base[self._escalate].sum())
        tot = max(wk + we, 1e-12)
        self._resolved = (wk / tot) * Y + (we / tot) * Y2
        # served-weight fraction: the tier already served q1 of the full
        # weight; the escalation restores the dropped share at its own
        # (possibly degraded) quality
        q1 = getattr(self._inner, "quality", 1.0)
        q2 = getattr(h2, "quality", 1.0)
        self._quality = min(1.0, q1 + (we / tot) * q2)
        return self._resolved
