"""Per-stage timing counters for the serving hot path (DESIGN.md §6).

Stages (one wall-clock accumulator each, shared by all threads):
  ``batcher_wait``   time a batcher spends blocked on its input queue,
  ``batch_fill``     copying segment rows into ring-buffer slots,
  ``predict``        jitted-step dispatch (async — excludes device time),
  ``transfer``       device sync + device->host fetch in the sender,
  ``combine``        device-partial / accumulator fold time.

float += under the GIL is atomic enough for counters; a lock would cost more
than the statistic is worth, so snapshots are only approximately consistent.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict


class StageTimers:
    def __init__(self):
        self.total_s: Dict[str, float] = defaultdict(float)
        self.count: Dict[str, int] = defaultdict(int)

    def add(self, stage: str, dt: float) -> None:
        self.total_s[stage] += dt
        self.count[stage] += 1

    def timed(self, stage: str, t0: float) -> float:
        """Record ``now - t0`` under ``stage``; returns now (chains stages)."""
        now = time.perf_counter()
        self.add(stage, now - t0)
        return now

    def reset(self) -> None:
        self.total_s.clear()
        self.count.clear()

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {stage: {"total_s": self.total_s[stage],
                        "count": self.count[stage],
                        "mean_ms": (1e3 * self.total_s[stage] /
                                    max(self.count[stage], 1))}
                for stage in sorted(self.total_s)}
