"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table3,...]

Emits CSV lines ``<table>:<fields...>`` so results can be grepped/diffed.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: overhead,table1,table3,stability,roofline")
    args = ap.parse_args()
    want = set(filter(None, args.only.split(",")))

    from benchmarks import overhead, roofline_report, stability, table1_throughput, table3_bbs
    jobs = [
        ("overhead", overhead.run),          # paper §IV.A
        ("table1", table1_throughput.run),   # paper Table I
        ("table3", table3_bbs.run),          # paper Table III
        ("stability", stability.run),        # paper §IV.B
        ("roofline", roofline_report.run),   # deliverable (g)
    ]
    for name, fn in jobs:
        if want and name not in want:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}:ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
