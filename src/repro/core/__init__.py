"""The paper's primary contribution: the allocation matrix, its optimizer
(worst-fit-decreasing + bounded greedy), the bench backends, and the BBS
baseline."""
from repro.core.allocation import (DEFAULT_BATCH_SIZES, AllocationMatrix,
                                   zeros)
from repro.core.bbs import best_batch_strategy
from repro.core.bench import AnalyticBench, MeasuredBench, MemoBench
from repro.core.devices import DeviceSpec, host_cpus, simulated_gpus, tpu_cells
from repro.core.greedy import bounded_greedy
from repro.core.optimizer import AllocationOptimizer, OptimizationResult
from repro.core.worst_fit import AllocationError, worst_fit_decreasing

__all__ = [
    "AllocationMatrix", "zeros", "DEFAULT_BATCH_SIZES", "DeviceSpec",
    "host_cpus", "simulated_gpus", "tpu_cells", "AnalyticBench",
    "MeasuredBench", "MemoBench", "worst_fit_decreasing", "AllocationError",
    "bounded_greedy", "AllocationOptimizer", "OptimizationResult",
    "best_batch_strategy",
]
