"""Training step & loop: next-token cross-entropy, remat, grad-accumulation."""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.training import optimizer as opt


def loss_fn(params, cfg: ModelConfig, tokens, labels, frontend=None, *,
            use_kernel: bool = False, remat: bool = False):
    """Next-token CE; label -100 and vocab padding are masked."""
    logits, aux = forward(params, cfg, tokens, frontend,
                          use_kernel=use_kernel, remat=remat)
    logits = logits.astype(jnp.float32)
    vocab = cfg.vocab_size
    pad = logits.shape[-1] - vocab
    if pad:
        neg = jnp.full((1, 1, pad), -1e30, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((1, 1, vocab)), neg], axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig, *,
                    use_kernel: bool = False, remat: bool = True,
                    accum_steps: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch``: {"tokens": (B,S), "labels": (B,S)[, "frontend": (B,F,D)]}.
    With accum_steps > 1 the batch's leading dim is split into microbatches
    and gradients are averaged in a lax.scan (memory-bounded large batch).
    """
    def fwd(params, tokens, labels, frontend):
        return loss_fn(params, cfg, tokens, labels, frontend,
                       use_kernel=use_kernel, remat=remat)

    grad_fn = jax.value_and_grad(fwd, has_aux=True)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        frontend = batch.get("frontend")
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, frontend)
        else:
            b = tokens.shape[0] // accum_steps

            def micro(carry, idx):
                gacc, lacc = carry
                sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * b, b, 0)
                fe = sl(frontend) if frontend is not None else None
                (l, _), g = grad_fn(params, sl(tokens), sl(labels), fe)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0),
                                           jnp.arange(accum_steps))
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}
        params, opt_state, om = opt.apply(ocfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, params, data: Iterator[Dict[str, Any]],
          ocfg: Optional[opt.AdamWConfig] = None, *, steps: int = 100,
          log_every: int = 10, use_kernel: bool = False, remat: bool = True,
          accum_steps: int = 1, callback: Optional[Callable] = None):
    """Simple single-host loop (examples / tests).  Returns (params, history)."""
    ocfg = ocfg or opt.AdamWConfig(total_steps=steps)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg, use_kernel=use_kernel,
                                      remat=remat, accum_steps=accum_steps))
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data)
        params, state, metrics = step_fn(params, state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return params, history
