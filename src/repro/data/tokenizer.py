"""Byte-level tokenizer: utf-8 bytes + BOS/EOS/PAD specials.

Vocab = 256 byte values + 3 specials = 259 (pad to the model's vocab via
modulo guard).  Enough substrate for real-text smoke training and for
serving text through the HTTP API without external deps.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 259


def encode(text: str, *, bos: bool = True, eos: bool = False) -> List[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return ids


def decode(ids: Iterable[int]) -> str:
    data = bytes(i for i in ids if 0 <= i < 256)
    return data.decode("utf-8", errors="replace")


def encode_batch(texts: Iterable[str], seq_len: int, *,
                 vocab_size: int = 0) -> np.ndarray:
    """(N, seq_len) int32, right-padded/truncated; ids clipped into the
    model's vocab when it is smaller than 259."""
    rows = []
    for t in texts:
        ids = encode(t)[:seq_len]
        ids = ids + [PAD] * (seq_len - len(ids))
        rows.append(ids)
    arr = np.asarray(rows, np.int32)
    if vocab_size and vocab_size < VOCAB_SIZE:
        arr = arr % vocab_size
    return arr


class TextCorpus:
    """Training iterator over a text corpus with the byte tokenizer."""

    def __init__(self, text: str, seq_len: int, *, seed: int = 0,
                 vocab_size: int = VOCAB_SIZE):
        ids = np.asarray(encode(text, bos=False), np.int32)
        if vocab_size < VOCAB_SIZE:
            ids = ids % vocab_size
        if len(ids) < seq_len + 2:
            reps = (seq_len + 2) // max(len(ids), 1) + 1
            ids = np.tile(ids, reps)
        self.ids = ids
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)

    def batch(self, batch_size: int):
        starts = self.rng.integers(0, len(self.ids) - self.seq_len - 1,
                                   batch_size)
        tok = np.stack([self.ids[s:s + self.seq_len] for s in starts])
        lab = np.stack([self.ids[s + 1:s + self.seq_len + 1] for s in starts])
        return {"tokens": tok.astype(np.int32), "labels": lab.astype(np.int32)}

    def iterator(self, batch_size: int):
        while True:
            yield self.batch(batch_size)
