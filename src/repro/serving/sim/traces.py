"""Synthetic trace generators: Poisson, bursty (MMPP), diurnal.

All generators take an explicit ``seed`` and return a time-sorted list of
:class:`~repro.serving.trace.TraceEvent` — the same schema recorded live,
so synthetic and recorded traces are interchangeable everywhere.

Request *shapes* (rows / priority / deadline / member subset) are drawn by
a shared :func:`_shape_mix` sampler parameterized per call; arrival *times*
are what distinguish the generators:

* :func:`poisson_trace` — homogeneous Poisson arrivals (exp inter-arrival).
* :func:`mmpp_trace` — 2-state Markov-modulated Poisson process: a calm
  state and a burst state with independent rates and exponential dwell
  times.  The standard bursty-traffic model.
* :func:`diurnal_trace` — inhomogeneous Poisson via thinning, with a
  sinusoidal per-member demand split: member groups wax and wane in
  anti-phase, the pattern the forecaster (DESIGN.md §12) exists to exploit.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.trace import TraceEvent

__all__ = ["poisson_trace", "mmpp_trace", "diurnal_trace"]


def _shape_mix(rng: np.random.Generator, n: int, *, rows, high_fraction: float,
               deadline_ms, members_choices) -> List[Tuple]:
    """Draw n (rows, priority, deadline_ms, members) tuples."""
    if np.isscalar(rows):
        rows_arr = np.full(n, int(rows))
    else:
        rows_arr = rng.choice(np.asarray(rows, dtype=np.int64), size=n)
    high = rng.random(n) < high_fraction
    if members_choices is None:
        midx = None
    else:
        midx = rng.integers(0, len(members_choices), size=n)
    out = []
    for i in range(n):
        members = None if midx is None else members_choices[int(midx[i])]
        out.append((int(rows_arr[i]), "high" if high[i] else "normal",
                    deadline_ms, members))
    return out


def _events(times: np.ndarray, shapes: List[Tuple]) -> List[TraceEvent]:
    return [TraceEvent(t=float(t), rows=r, priority=p, deadline_ms=d,
                       members=m)
            for t, (r, p, d, m) in zip(times, shapes)]


def poisson_trace(n: int, rate: float, *, seed: int, rows=8,
                  high_fraction: float = 0.0,
                  deadline_ms: Optional[float] = None,
                  members_choices: Optional[Sequence[Sequence[int]]] = None,
                  ) -> List[TraceEvent]:
    """``n`` arrivals at ``rate`` requests/s (homogeneous Poisson)."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    shapes = _shape_mix(rng, n, rows=rows, high_fraction=high_fraction,
                        deadline_ms=deadline_ms,
                        members_choices=members_choices)
    return _events(times, shapes)


def mmpp_trace(n: int, *, seed: int, calm_rate: float, burst_rate: float,
               mean_calm_s: float = 1.0, mean_burst_s: float = 0.1,
               rows=8, high_fraction: float = 0.0,
               deadline_ms: Optional[float] = None,
               members_choices: Optional[Sequence[Sequence[int]]] = None,
               ) -> List[TraceEvent]:
    """2-state Markov-modulated Poisson process (bursty arrivals)."""
    rng = np.random.default_rng(seed)
    times = np.empty(n)
    t = 0.0
    burst = False
    state_end = rng.exponential(mean_calm_s)
    for i in range(n):
        while True:
            rate = burst_rate if burst else calm_rate
            dt = rng.exponential(1.0 / rate)
            if t + dt <= state_end:
                t += dt
                break
            # jump to the state boundary and flip; redraw in the new state
            t = state_end
            burst = not burst
            state_end = t + rng.exponential(
                mean_burst_s if burst else mean_calm_s)
        times[i] = t
    shapes = _shape_mix(rng, n, rows=rows, high_fraction=high_fraction,
                        deadline_ms=deadline_ms,
                        members_choices=members_choices)
    return _events(times, shapes)


def diurnal_trace(n: int, *, seed: int, rate: float, period_s: float,
                  amplitude: float = 0.4, members_groups:
                  Sequence[Sequence[int]] = ((0,), (1,)), rows=8,
                  high_fraction: float = 0.0,
                  deadline_ms: Optional[float] = None) -> List[TraceEvent]:
    """Constant total ``rate`` with a sinusoidal demand split across
    ``members_groups``: group 0's share is ``0.5 + amplitude·sin(2πt/P)``,
    group 1's the complement (extra groups split the remainder evenly).
    This is the planner's hard case — total load is steady, so only a
    per-member view (EWMA or forecast) sees the wave coming.
    """
    if not 0.0 < amplitude < 0.5:
        raise ValueError("amplitude must be in (0, 0.5)")
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    u = rng.random(n)
    shapes = _shape_mix(rng, n, rows=rows, high_fraction=high_fraction,
                        deadline_ms=deadline_ms, members_choices=None)
    groups = [tuple(g) for g in members_groups]
    out = []
    for i, t in enumerate(times):
        share0 = 0.5 + amplitude * math.sin(2.0 * math.pi * t / period_s)
        if u[i] < share0 or len(groups) == 1:
            g = groups[0]
        elif len(groups) == 2:
            g = groups[1]
        else:
            rest = (u[i] - share0) / max(1e-12, 1.0 - share0)
            g = groups[1 + min(len(groups) - 2,
                               int(rest * (len(groups) - 1)))]
        rows_i, pri, dl, _ = shapes[i]
        out.append(TraceEvent(t=float(t), rows=rows_i, priority=pri,
                              deadline_ms=dl, members=g))
    return out
