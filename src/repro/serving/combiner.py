"""Device-resident partial ensemble combine (DESIGN.md §4).

Workers co-located on one device fold their weighted predictions into a
shared per-(request, segment) partial *on the device* and post **one**
``Message(s, None, partial, rid, count)`` per device per segment — instead of
one {s, m, P} message (and one device->host transfer) per member.  With M
members sharing a device this cuts accumulator traffic by up to M×.

How the flush trigger stays deterministic: the broadcaster assigns every
(segment, model) pair to a *specific* worker instance (round-robin striping
across data-parallel instances, system.py), so at ``begin()`` time the system
knows exactly how many member contributions each device will produce for each
segment.  The combiner flushes a segment the moment its count is reached.

Combination rules are applied member-side, so the partial is always additive:
  mean/weighted  partial += w_m · P_m
  vote           partial += w_vote · onehot(argmax P_m)
  pallas         partial  = ensemble_combine(P_m[None], [w_m], partial) — the
                 accumulate-into-partial Pallas kernel variant
and the accumulator's per-message work collapses to ``Y[lo:hi] += partial``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serving.metrics import StageTimers
from repro.serving.segments import Message, Request


class _SegPartial:
    __slots__ = ("acc", "got")

    def __init__(self):
        self.acc = None        # np.ndarray or jax.Array (device-resident)
        self.got = 0


class DeviceCombiner:
    """One per device hosting >= 1 worker.  ``add()`` is called from worker
    sender threads; a per-combiner lock serializes the fold bookkeeping (the
    device math itself is dispatched asynchronously)."""

    def __init__(self, name: str, prediction_queue: "queue.Queue[Message]",
                 timers: Optional[StageTimers] = None):
        self.name = name
        self.prediction_queue = prediction_queue
        self.timers = timers
        self._lock = threading.Lock()
        # rid -> {s: expected contribution count} (segments with count > 0)
        self._expected: Dict[int, Dict[int, int]] = {}
        self._parts: Dict[Tuple[int, int], _SegPartial] = {}
        self.partials_posted = 0

    # ---- request lifecycle ---------------------------------------------------
    def begin(self, req: Request, expected: Dict[int, int]) -> None:
        """Register how many member contributions each segment of ``req``
        will see on this device."""
        with self._lock:
            self._expected[req.rid] = {s: n for s, n in expected.items() if n}

    def finish(self, rid: int) -> None:
        """Drop any state for a completed/failed request (idempotent)."""
        with self._lock:
            self._expected.pop(rid, None)
            for key in [k for k in self._parts if k[0] == rid]:
                del self._parts[key]

    # ---- the fold ------------------------------------------------------------
    def add(self, req: Request, s: int, m: int, P) -> None:
        """Fold member ``m``'s segment-``s`` prediction into the device
        partial; post the partial once the segment's expected count is
        reached.  ``P`` may be a numpy array (fake workers) or a device
        array — device arrays stay resident until the single flush
        transfer."""
        t0 = time.perf_counter()
        flush = None
        # the heavy elementwise math runs outside the lock; only the
        # accumulate + bookkeeping is serialized
        contrib = self._contribution(req, P, req.weights[m])
        with self._lock:
            expected = self._expected.get(req.rid)
            if expected is None or s not in expected:   # request torn down
                return
            part = self._parts.setdefault((req.rid, s), _SegPartial())
            part.acc = self._fold(req, part.acc, contrib, req.weights[m])
            part.got += 1
            if part.got >= expected[s]:
                flush = part
                del self._parts[(req.rid, s)]
                del expected[s]
                if not expected:
                    del self._expected[req.rid]
        if flush is not None:
            # the single device->host transfer per device per segment
            self.prediction_queue.put(Message(
                s, None, np.asarray(flush.acc), rid=req.rid, count=flush.got))
            self.partials_posted += 1
        if self.timers is not None:
            self.timers.add("combine", time.perf_counter() - t0)

    @staticmethod
    def _contribution(req: Request, P, w: float):
        """Member's additive contribution (weighted prediction / vote).  For
        the pallas rule the raw device array passes through: the weighting is
        fused into the accumulate kernel at fold time."""
        if req.combine == "vote":
            if isinstance(P, np.ndarray):
                contrib = np.zeros((P.shape[0], req.num_classes), np.float32)
                contrib[np.arange(P.shape[0]), P.argmax(axis=1)] = w
                return contrib
            import jax
            return w * jax.nn.one_hot(P.argmax(axis=-1), req.num_classes,
                                      dtype=np.float32)
        if req.combine == "pallas" and not isinstance(P, np.ndarray):
            return P
        # mean / weighted (and pallas with host arrays from fake workers)
        return P * np.float32(w)

    @staticmethod
    def _fold(req: Request, acc, contrib, w: float):
        if req.combine == "pallas" and not isinstance(contrib, np.ndarray):
            import jax.numpy as jnp
            from repro.kernels import ops as kops
            if acc is None:
                acc = jnp.zeros(contrib.shape, jnp.float32)
            # the accumulate-into-partial Pallas kernel variant
            return kops.ensemble_accumulate(
                acc, contrib[None].astype(jnp.float32),
                jnp.full((1,), w, jnp.float32))
        if acc is None:
            return contrib
        if isinstance(acc, np.ndarray):
            acc += contrib                     # in-place: no temp per fold
            return acc
        return acc + contrib
