"""Quantized member execution (DESIGN.md §14): per-channel int8/fp8 params,
the fused dequant-weight-accumulate combine epilogue, precision-floor
routing, dtype-aware allocator footprints, and the live EDF dispatch queue.

Hot-path correctness contract: a quantized system's combine output tracks
the fp32 reference within quantization tolerance (per-row logit scales are
uniform across classes, so vote/argmax are unaffected), and *within* one
precision mode results stay deterministic — the chaos-band tests check
chunk replay is bit-identical and mid-flight demotion matches a direct
member subset, both under int8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.core import memory as mem
from repro.core.worst_fit import worst_fit_decreasing
from repro.kernels import ops
from repro.kernels import quant as kq
from repro.serving.admission import DispatchQueue, EDFDispatchQueue
from repro.serving.segments import MemberUnavailable, PredictOptions
from repro.serving.system import InferenceSystem

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    A = np.array(A)
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    kw.setdefault("max_seq", SEQ)
    return InferenceSystem(cfgs, params, alloc, **kw)


def _X(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 512, (n, SEQ)).astype(np.int32)


# ---- shared quantization helpers ---------------------------------------------

def test_param_quantization_roundtrip():
    cfg = ensemble("ENS4")[0]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qp = kq.quantize_params(params, "int8")
    rp = kq.dequantize_params(qp)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(rp)):
        scale = float(jnp.abs(a).max()) or 1.0
        assert float(jnp.abs(a - b).max()) < 0.02 * scale
    # narrow storage: ~4x smaller than fp32 (scales + fp32 1-D leaves ride)
    fp32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    assert kq.quantized_param_bytes(params, "int8") < 0.4 * fp32_bytes


def test_bf16_params_halve_bytes_and_track():
    cfg = ensemble("ENS4")[0]
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    qp = kq.quantize_params(params, "bf16")
    fp32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    assert kq.quantized_param_bytes(params, "bf16") < 0.6 * fp32_bytes
    rp = kq.dequantize_params(qp)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(rp)):
        scale = float(jnp.abs(a).max()) or 1.0
        assert float(jnp.abs(a - b).max()) < 0.01 * scale


def test_meets_precision_ordering():
    assert kq.meets_precision("fp32", None)
    assert kq.meets_precision(None, "fp32")          # None member -> fp32
    assert kq.meets_precision("fp32", "int8")        # better satisfies floor
    assert kq.meets_precision("bf16", "int8")
    assert kq.meets_precision("int8", "fp8")         # int8 == fp8 rank
    assert not kq.meets_precision("int8", "bf16")
    assert not kq.meets_precision("bf16", "fp32")
    with pytest.raises(ValueError):
        kq.meets_precision("fp32", "int4")


def test_predict_options_validates_member_dtype():
    PredictOptions(member_dtype="int8")              # ok
    with pytest.raises(ValueError):
        PredictOptions(member_dtype="int4")


# ---- fused dequant-weight-accumulate epilogue --------------------------------

@pytest.mark.parametrize("m,seg,c", [(1, 8, 512), (3, 40, 512), (2, 128, 640)])
def test_fused_quant_accumulate_matches_reference(m, seg, c):
    rng = np.random.default_rng(seg)
    logits = rng.normal(size=(m, seg, c)).astype(np.float32) * 4.0
    partial = rng.normal(size=(seg, c)).astype(np.float32)
    w = rng.uniform(0.1, 1.0, m).astype(np.float32)
    qs = [kq.quantize_symmetric(jnp.asarray(x), axis=-1) for x in logits]
    q = jnp.stack([a for a, _ in qs])
    s = jnp.stack([b[:, 0] for _, b in qs])          # (m, seg)
    out = ops.ensemble_accumulate_quant(
        jnp.asarray(partial), q, s, jnp.asarray(w))
    ref = partial + sum(
        np.asarray(kq.dequantize(qs[i][0], qs[i][1])) * w[i]
        for i in range(m))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_fused_quant_fp8_matches_reference():
    if kq._FP8_DTYPE is None:
        pytest.skip("no fp8 in this jax build")
    rng = np.random.default_rng(7)
    logits = rng.normal(size=(2, 16, 512)).astype(np.float32)
    partial = np.zeros((16, 512), np.float32)
    qs = [kq.quantize_symmetric(jnp.asarray(x), axis=-1, dtype="fp8")
          for x in logits]
    out = ops.ensemble_accumulate_quant(
        jnp.asarray(partial), jnp.stack([a for a, _ in qs]),
        jnp.stack([b[:, 0] for _, b in qs]), jnp.full((2,), 0.5, jnp.float32))
    ref = sum(np.asarray(kq.dequantize(a, b)) * 0.5 for a, b in qs)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


# ---- end-to-end: quantized system vs fp32 reference --------------------------

def _rel_err(y, yref):
    return float(np.abs(y - yref).max() / max(np.abs(yref).max(), 1e-6))


def test_int8_system_tracks_fp32(ens2):
    cfgs, params = ens2
    X = _X(70)
    with make_system(cfgs, params, [[8, 16]], segment_size=32) as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 16]], segment_size=32,
                     member_dtypes=["int8", "int8"]) as s:
        Y = s.predict(X)
    assert Y.shape == Yref.shape
    assert _rel_err(Y, Yref) < 0.05


@pytest.mark.parametrize("combine", ["pallas", "weighted"])
def test_int8_combine_rules_track_fp32(ens2, combine):
    cfgs, params = ens2
    X = _X(40, seed=3)
    w = np.array([0.7, 0.3], np.float32) if combine == "weighted" else None
    kw = dict(segment_size=16, combine=combine)
    if w is not None:
        kw["weights"] = w
    with make_system(cfgs, params, [[8, 8]], **kw) as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 8]],
                     member_dtypes=["int8", "int8"], **kw) as s:
        Y = s.predict(X)
    assert _rel_err(Y, Yref) < 0.05


def test_int8_vote_matches_fp32_argmax(ens2):
    """Per-row scales are positive and uniform across classes, so voting on
    the raw int8 logits preserves fp32 argmax — except where two classes sit
    within one quantization step of each other (rare near-ties may flip)."""
    cfgs, params = ens2
    X = _X(24, seed=4)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     combine="vote") as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 8]], segment_size=16, combine="vote",
                     member_dtypes=["int8", "int8"]) as s:
        Y = s.predict(X)
    # votes stay normalized and nearly all rows vote identically
    np.testing.assert_allclose(Y.sum(axis=1), 1.0, atol=1e-6)
    agree = (np.abs(Y - Yref).max(axis=1) < 1e-6).mean()
    assert agree >= 0.9, f"vote agreement {agree:.2f}"


def test_int8_member_subsets_track_fp32(ens2):
    cfgs, params = ens2
    X = _X(20, seed=5)
    with make_system(cfgs, params, [[8, 8]], segment_size=16) as sref, \
            make_system(cfgs, params, [[8, 8]], segment_size=16,
                        member_dtypes=["int8", "int8"]) as s:
        for members in ([0], [1], [0, 1]):
            Y = s.predict(X, members=members)
            Yref = sref.predict(X, members=members)
            assert _rel_err(Y, Yref) < 0.05, members


def test_int8_host_combine_path(ens2):
    """device_combine=False: no device-resident partials, so workers ship
    fp32 logits computed from quantized params (no logit quantization)."""
    cfgs, params = ens2
    X = _X(30, seed=6)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     device_combine=False) as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     device_combine=False,
                     member_dtypes=["int8", "int8"]) as s:
        Y = s.predict(X)
    assert _rel_err(Y, Yref) < 0.05


def test_mixed_precision_ensemble(ens2):
    """int8 + fp32 members coexist; the combiner folds tuple and plain
    contributions into one partial."""
    cfgs, params = ens2
    X = _X(40, seed=8)
    with make_system(cfgs, params, [[8, 8]], segment_size=16) as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     member_dtypes=["int8", "fp32"]) as s:
        Y = s.predict(X)
    assert _rel_err(Y, Yref) < 0.05


def test_h2d_staging_counter(ens2):
    """Multi-chunk segments drive the double-buffered staging path: chunk
    N+1's upload is issued while chunk N computes."""
    cfgs, params = ens2
    X = _X(128, seed=9)
    with make_system(cfgs, params, [[8, 8]], segment_size=64) as s:
        Y = s.predict(X)
        staged = sum(w.timers.counters.get("h2d_staged", 0)
                     for w in s.workers)
    assert Y.shape == (128, cfgs[0].vocab_size)
    assert staged > 0


# ---- precision-floor routing -------------------------------------------------

def test_precision_floor_filters_members(ens2):
    cfgs, params = ens2
    X = _X(20, seed=10)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     member_dtypes=["int8", "fp32"]) as s:
        y_fp32 = s.predict(X, options=PredictOptions(member_dtype="fp32"))
        y_m1 = s.predict(X, members=[1])
        np.testing.assert_allclose(y_fp32, y_m1, atol=1e-6)
        # floor at int8 admits everyone
        y_all = s.predict(X, options=PredictOptions(member_dtype="int8"))
        assert y_all.shape == y_fp32.shape
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     member_dtypes=["int8", "int8"]) as s:
        with pytest.raises(MemberUnavailable):
            s.predict(X, options=PredictOptions(member_dtype="fp32"))


# ---- dtype-aware allocator ---------------------------------------------------

def test_worker_bytes_dtype_aware():
    cfg = ensemble("ENS4")[0]
    b32 = mem.worker_bytes(cfg, 8, 128)
    b8 = mem.worker_bytes(cfg, 8, 128, member_dtype="int8")
    bb = mem.worker_bytes(cfg, 8, 128, member_dtype="bf16")
    assert b8 < b32 and bb < b32
    p32 = cfg.param_count() * 4
    # params term shrinks ~4x (scale overhead <5%); activations unchanged
    assert b32 - b8 > 0.70 * p32
    assert abs((b32 - bb) - 0.5 * p32) < 0.01 * p32


def test_quantized_members_double_packing_density():
    """Worst-fit packs ~2x+ members per device once params go int8: a memory
    budget that cannot hold the ensemble at fp32 holds all of it quantized
    (at short seq the param term dominates, so int8 is ~3x denser)."""
    from repro.core.worst_fit import AllocationError
    cfgs = ensemble("ENS4")
    dts = ["int8"] * len(cfgs)
    f32 = sum(mem.worker_bytes(c, 8, SEQ) for c in cfgs)
    f8 = sum(mem.worker_bytes(c, 8, SEQ, member_dtype="int8") for c in cfgs)
    assert f8 < 0.5 * f32
    devs = host_cpus(1, memory_bytes=int(0.5 * f32))
    with pytest.raises(AllocationError):
        worst_fit_decreasing(cfgs, devs, seq=SEQ)
    a8 = worst_fit_decreasing(cfgs, devs, seq=SEQ, member_dtypes=dts)
    assert int((a8.A > 0).sum()) == len(cfgs)   # every member placed
    assert mem.fit_mem(a8, cfgs, SEQ, member_dtypes=dts)


# ---- live EDF dispatch queue -------------------------------------------------

def test_dispatch_queue_selection(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, [[8, 8]], segment_size=16) as s:
        assert all(type(w._dispatch_q) is DispatchQueue for w in s.workers)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     dispatch_queue="edf") as s:
        assert all(isinstance(w._dispatch_q, EDFDispatchQueue)
                   for w in s.workers)
        Y = s.predict(_X(40, seed=11))
    assert Y.shape == (40, cfgs[0].vocab_size)
    with pytest.raises(ValueError):
        make_system(cfgs, params, [[8, 8]], dispatch_queue="lifo")


def test_edf_queue_matches_fifo_results(ens2):
    """EDF only reorders dispatch; values are combine-order independent."""
    cfgs, params = ens2
    X = _X(64, seed=12)
    with make_system(cfgs, params, [[8, 8]], segment_size=16) as s:
        Yref = s.predict(X)
    with make_system(cfgs, params, [[8, 8]], segment_size=16,
                     dispatch_queue="edf") as s:
        Y = s.predict(X)
    np.testing.assert_allclose(Y, Yref, atol=1e-5)


def test_member_dtypes_validation(ens2):
    cfgs, params = ens2
    with pytest.raises(ValueError):
        make_system(cfgs, params, [[8, 8]], member_dtypes=["int8"])  # len
    with pytest.raises(ValueError):
        make_system(cfgs, params, [[8, 8]], member_dtypes=["int4", "fp32"])


# ---- chaos band: determinism within a precision mode -------------------------

@pytest.mark.chaos
def test_int8_chunk_replay_bit_identical(ens2):
    """Replay after a sibling crash re-runs the same quantized compiled fn
    at the same shape: bit-identical to a fault-free int8 run."""
    from repro.serving.faults import FaultPlan, FaultSpec
    cfgs, params = ens2
    A = [[8, 8], [8, 0]]
    Xs = [_X(8, seed=i) for i in range(8)]

    def run(fault_plan):
        s = make_system(cfgs, params, A, segment_size=8, watchdog_s=60.0,
                        supervise=True, supervise_interval_s=0.02,
                        member_dtypes=["int8", "int8"],
                        fault_plan=fault_plan)
        try:
            hs = [s.predict_async(x) for x in Xs]
            return [np.array(h.result(120.0)) for h in hs], \
                [h.quality for h in hs]
        finally:
            s.shutdown()

    base, _ = run(None)
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=1,
                             worker="w1.0"))
    faulted, quals = run(fp)
    assert all(q == 1.0 for q in quals)
    for i, (yb, yf) in enumerate(zip(base, faulted)):
        np.testing.assert_array_equal(yb, yf, err_msg=f"request {i}")


@pytest.mark.chaos
def test_int8_midflight_demotion_matches_direct_subset(ens2):
    """Brownout demotion + forgiveness under quantized execution: demoting
    member 1 mid-flight equals asking for members=[0] up front, both on the
    int8 path."""
    from repro.serving.faults import FaultPlan, FaultSpec
    cfgs, params = ens2
    fp = FaultPlan(FaultSpec(stage="predictor", kind="slow", stall_s=0.05,
                             repeat=True, worker="w1"))
    s = make_system(cfgs, params, [[8, 8]], supervise=True,
                    member_dtypes=["int8", "int8"], fault_plan=fp)
    try:
        X = _X(64, seed=13)
        Yref = s.predict(X, members=[0], timeout=60.0)
        h = s.predict_async(X)
        assert s.demote_request(h.req.rid, {0})
        Y = h.result(60.0)
        assert np.allclose(Y, Yref, atol=1e-5)
        assert h.quality < 1.0
        assert s.serving_counters().get("requests_demoted") == 1
    finally:
        s.shutdown()
