"""Per-stage timing counters + serving gauges for the hot path (DESIGN.md §6).

Stages (one wall-clock accumulator each, shared by all threads):
  ``batcher_wait``   time a batcher spends blocked on its input queue,
  ``batch_fill``     copying request rows into coalesced batch slots,
  ``dispatch_wait.high`` / ``dispatch_wait.normal``
                     per-class time a chunk waits in the priority dispatch
                     queue between batcher and predictor (the preemption
                     lever: high should stay near zero under bulk load),
  ``predict``        jitted-step dispatch (async — excludes device time),
  ``transfer``       device sync + device->host fetch in the sender,
  ``combine``        device-partial / accumulator fold time.

Counters (monotonic sums) instrument the coalescing scheduler:
  ``rows_valid``       request rows dispatched to the device,
  ``rows_dispatched``  rows actually sent including bucket padding,
  ``rows_dropped``     rows of cancelled/expired requests dropped before
                       (or instead of) device time,
  ``batches``          compiled-batch dispatches,
  ``spans``            (request, segment, row-range) spans packed into
                       batches — spans/batches is the coalescing factor.

Gauges record last/max/mean of a sampled value (e.g.
``queue_depth.<worker_id>``, that batcher's input-queue backlog at each
drain; ``hp_p50_ms``, the rolling high-priority median request latency).

Latency reservoirs keep the most recent ``LATENCY_WINDOW`` end-to-end
request latencies per priority class; ``latency_snapshot()`` turns them
into p50/p99 — the SLO view `/metrics` exports (``hp_p50`` etc.).

float += under the GIL is atomic enough for counters; a lock would cost more
than the statistic is worth, so snapshots are only approximately consistent.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict, deque
from typing import Dict, List

LATENCY_WINDOW = 512      # recent completions kept per priority class


class StageTimers:
    def __init__(self):
        self.total_s: Dict[str, float] = defaultdict(float)
        self.count: Dict[str, int] = defaultdict(int)
        self.counters: Dict[str, float] = defaultdict(float)
        self._gauges: Dict[str, List[float]] = {}   # name -> [last,max,sum,n]
        # latency reservoirs get a real lock (unlike the counters): the
        # snapshot ITERATES the deques/dict, and CPython raises if another
        # thread appends mid-iteration — recording is per-request (not
        # per-chunk), so the lock is off the hot path
        self._latency: Dict[str, "deque[float]"] = {}   # class -> recent s
        self._lat_lock = threading.Lock()

    def add(self, stage: str, dt: float) -> None:
        self.total_s[stage] += dt
        self.count[stage] += 1

    def timed(self, stage: str, t0: float) -> float:
        """Record ``now - t0`` under ``stage``; returns now (chains stages)."""
        now = time.perf_counter()
        self.add(stage, now - t0)
        return now

    # ---- counters / gauges ---------------------------------------------------
    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def gauge(self, name: str, v: float) -> None:
        g = self._gauges.get(name)
        if g is None:
            self._gauges[name] = [v, v, v, 1]
        else:
            g[0] = v
            g[1] = max(g[1], v)
            g[2] += v
            g[3] += 1

    # ---- per-class request latency (SLO view, DESIGN.md §7) ------------------
    def latency(self, cls: str, dt: float) -> None:
        """Record one completed request's end-to-end latency under priority
        class ``cls`` ("high"/"normal").  High-priority completions also
        refresh the ``hp_p50_ms`` gauge, so the rolling median is visible
        wherever gauges are (high traffic is sparse by design — the sort is
        bounded by LATENCY_WINDOW and off the bulk path)."""
        with self._lat_lock:
            d = self._latency.get(cls)
            if d is None:
                d = self._latency[cls] = deque(maxlen=LATENCY_WINDOW)
            d.append(dt)
            if cls == "high":
                self.gauge("hp_p50_ms", 1e3 * sorted(d)[(len(d) - 1) // 2])

    def latency_snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-class {p50_ms, p99_ms, n} over the rolling window."""
        out = {}
        with self._lat_lock:
            classes = {cls: list(d) for cls, d in self._latency.items()}
        for cls, vals in sorted(classes.items()):
            arr = sorted(vals)
            n = len(arr)
            if not n:
                continue
            out[cls] = {"n": n,
                        "p50_ms": 1e3 * arr[(n - 1) // 2],
                        "p99_ms": 1e3 * arr[min(n - 1, int(0.99 * n))]}
        return out

    def padding_efficiency(self) -> float:
        """Valid rows / dispatched rows (1.0 = no padding waste)."""
        dispatched = self.counters.get("rows_dispatched", 0.0)
        if dispatched <= 0:
            return 1.0
        return self.counters.get("rows_valid", 0.0) / dispatched

    def reset(self) -> None:
        self.total_s.clear()
        self.count.clear()
        self.counters.clear()
        self._gauges.clear()
        with self._lat_lock:
            self._latency.clear()

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {stage: {"total_s": self.total_s[stage],
                        "count": self.count[stage],
                        "mean_ms": (1e3 * self.total_s[stage] /
                                    max(self.count[stage], 1))}
                for stage in sorted(self.total_s)}

    def counter_snapshot(self) -> Dict[str, float]:
        return dict(self.counters)

    def gauge_snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: {"last": g[0], "max": g[1], "mean": g[2] / max(g[3], 1)}
                for name, g in sorted(self._gauges.items())}
