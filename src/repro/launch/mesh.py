"""Production meshes.

Single pod: (16, 16) over ("data", "model") — 256 TPU v5e chips.
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips, the "pod"
axis crossing DCI between two pods.

Functions (not module-level constants) so importing this module never touches
jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh on whatever devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes a global batch dim is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def batch_axis_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
