"""Auto-tuning the dispatch-ahead window K in-sim (ROADMAP item l).

K trades throughput against preemptibility: each predictor pop commits up
to K chunks as one non-preemptible group, amortizing the per-group
dispatch overhead (throughput ∝ K·s/(h + K·s) under saturation) while a
high-priority chunk arriving mid-group waits out up to K−1 queued bulk
services.  The sweet spot depends on the workload's priority mix and the
overhead-to-service ratio — exactly what a trace + fitted
:class:`ServiceModel` capture, so the sweep runs in the simulator in
milliseconds instead of perturbing a live system.

Two objectives:

* ``"throughput"`` — the smallest K within ``tol`` of the best sustained
  throughput (smaller K = shorter committed window, so ties break toward
  preemptibility).  On a saturated bulk trace this reproduces the live
  default ``DISPATCH_AHEAD`` (gated in `sim.ktuner`).
* ``"latency"`` — among Ks within ``thr_slack`` of the best throughput,
  the one minimizing high-priority p99 (falling back to pooled p99 on a
  single-class trace).
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

from repro.serving.trace import TraceEvent

__all__ = ["tune_dispatch_ahead"]


def tune_dispatch_ahead(make_sim: Callable[[int], "SimSystem"],
                        trace: Sequence[TraceEvent], *,
                        ks: Sequence[int] = (1, 2, 4, 8, 16, 32),
                        objective: str = "throughput",
                        tol: float = 0.01,
                        thr_slack: float = 0.10) -> Dict:
    """Sweep ``ks``, running ``make_sim(k).run(trace)`` for each, and pick a
    recommendation per ``objective``.  ``make_sim`` must build a fresh
    system per call (sim state is single-use)."""
    per_k: Dict[int, dict] = {}
    for k in sorted(set(int(k) for k in ks)):
        sim = make_sim(k)
        sim.run(trace)
        r = sim.results()
        per_k[k] = {
            "throughput_rows_per_s": r["throughput_rows_per_s"],
            "p99_ms": r["p99_ms"],
            "hp_p99_ms": r.get("hp_p99_ms", r["p99_ms"]),
            "completed": r["completed"],
            "failed": r["failed"],
        }
    best_thr = max(v["throughput_rows_per_s"] for v in per_k.values())
    if objective == "throughput":
        rec = min(k for k, v in per_k.items()
                  if v["throughput_rows_per_s"] >= (1.0 - tol) * best_thr)
    elif objective == "latency":
        eligible = [k for k, v in per_k.items()
                    if v["throughput_rows_per_s"]
                    >= (1.0 - thr_slack) * best_thr]
        rec = min(eligible, key=lambda k: (per_k[k]["hp_p99_ms"], k))
    else:
        raise ValueError(f"unknown objective {objective!r}")
    return {"recommended": rec, "objective": objective,
            "best_throughput_rows_per_s": best_thr, "per_k": per_k}
