"""Per-stage timing counters + serving gauges for the hot path (DESIGN.md §6).

Stages (one wall-clock accumulator each, shared by all threads):
  ``batcher_wait``   time a batcher spends blocked on its input queue,
  ``batch_fill``     copying request rows into coalesced batch slots,
  ``dispatch_wait.high`` / ``dispatch_wait.normal``
                     per-class time a chunk waits in the priority dispatch
                     queue between batcher and predictor (the preemption
                     lever: high should stay near zero under bulk load),
  ``predict``        jitted-step dispatch (async — excludes device time),
  ``transfer``       device sync + device->host fetch in the sender,
  ``combine``        device-partial / accumulator fold time.

Counters (monotonic sums) instrument the coalescing scheduler:
  ``rows_valid``       request rows dispatched to the device,
  ``rows_dispatched``  rows actually sent including bucket padding,
  ``rows_dropped``     rows of cancelled/expired requests dropped before
                       (or instead of) device time,
  ``batches``          compiled-batch dispatches,
  ``spans``            (request, segment, row-range) spans packed into
                       batches — spans/batches is the coalescing factor.

Gauges record last/max/mean of a sampled value (e.g.
``queue_depth.<worker_id>``, that batcher's input-queue backlog at each
drain; ``hp_p50_ms``, the rolling high-priority median request latency).
New gauge keys appear at runtime (a spawn adds ``queue_depth.<id>``), so
first-time insertion and ``gauge_snapshot()`` share a small lock — the
steady-state update path (in-place list mutation, no dict resize) stays
lock-free.

Per-class end-to-end request latency lands in fixed-bucket **log-scale
histograms** (``LATENCY_BOUNDS_S``: 100µs → ~148s at √2 per bucket), not a
bounded reservoir, so p50/p99 cover the whole run instead of the last
window under sustained load.  ``latency_snapshot()`` keeps its
{cls: {n, p50_ms, p99_ms}} shape (percentiles interpolated geometrically
within the matched bucket); ``latency_histogram()`` exposes the raw
buckets, and :func:`prometheus_text` renders the whole surface in
Prometheus text exposition format 0.0.4 for ``GET /metrics?format=prom``.

float += under the GIL is atomic enough for counters; a lock would cost more
than the statistic is worth, so snapshots are only approximately consistent.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Dict, List, Optional

LATENCY_WINDOW = 512      # retained for callers; histograms are unbounded

# log-spaced latency bucket upper bounds (seconds): 1e-4 * sqrt(2)^i.
# 42 finite buckets span 100µs .. ~148s; one overflow bucket above.
LATENCY_BOUNDS_S = tuple(1e-4 * 2.0 ** (i / 2.0) for i in range(42))
_SQRT2 = 2.0 ** 0.5

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _hist_percentile(counts: List[int], n: int, q: float) -> float:
    """Value estimate at quantile ``q`` from per-bucket counts (geometric
    interpolation inside the matched log bucket)."""
    if n <= 0:
        return 0.0
    rank = min(n - 1, int(q * n))
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum > rank:
            if i < len(LATENCY_BOUNDS_S):
                hi = LATENCY_BOUNDS_S[i]
                lo = LATENCY_BOUNDS_S[i - 1] if i else hi / _SQRT2
            else:                       # overflow bucket
                lo = LATENCY_BOUNDS_S[-1]
                hi = lo * _SQRT2
            return (lo * hi) ** 0.5
    return LATENCY_BOUNDS_S[-1]


class StageTimers:
    def __init__(self):
        self.total_s: Dict[str, float] = defaultdict(float)
        self.count: Dict[str, int] = defaultdict(int)
        self.counters: Dict[str, float] = defaultdict(float)
        self._gauges: Dict[str, List[float]] = {}   # name -> [last,max,sum,n]
        # new-key insertion resizes the dict, which races snapshot
        # iteration (workers add queue_depth.<id> after a spawn) — guard
        # both with a lock; the common existing-key update stays lock-free
        self._gauge_lock = threading.Lock()
        # latency histograms get a real lock (recording is per-request,
        # not per-chunk, so it is off the hot path): cls -> [counts, sum]
        self._latency: Dict[str, list] = {}
        self._lat_lock = threading.Lock()

    def add(self, stage: str, dt: float) -> None:
        self.total_s[stage] += dt
        self.count[stage] += 1

    def timed(self, stage: str, t0: float) -> float:
        """Record ``now - t0`` under ``stage``; returns now (chains stages)."""
        now = time.perf_counter()
        self.add(stage, now - t0)
        return now

    # ---- counters / gauges ---------------------------------------------------
    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def gauge(self, name: str, v: float) -> None:
        g = self._gauges.get(name)
        if g is None:
            with self._gauge_lock:
                g = self._gauges.setdefault(name, [v, v, 0.0, 0])
        g[0] = v
        g[1] = max(g[1], v)
        g[2] += v
        g[3] += 1

    # ---- per-class request latency (SLO view, DESIGN.md §7) ------------------
    def latency(self, cls: str, dt: float) -> None:
        """Record one completed request's end-to-end latency under priority
        class ``cls`` ("high"/"normal").  High-priority completions also
        refresh the ``hp_p50_ms`` gauge, so the rolling median is visible
        wherever gauges are (the bucket walk is O(buckets), off the bulk
        path)."""
        i = 0
        bounds = LATENCY_BOUNDS_S
        lo, hi = 0, len(bounds)
        while lo < hi:                  # first bound >= dt (bisect)
            mid = (lo + hi) // 2
            if bounds[mid] < dt:
                lo = mid + 1
            else:
                hi = mid
        i = lo                          # == len(bounds) -> overflow bucket
        with self._lat_lock:
            h = self._latency.get(cls)
            if h is None:
                h = self._latency[cls] = [[0] * (len(bounds) + 1), 0.0]
            h[0][i] += 1
            h[1] += dt
            if cls == "high":
                n = sum(h[0])
                self.gauge("hp_p50_ms",
                           1e3 * _hist_percentile(h[0], n, 0.50))

    def latency_snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-class {p50_ms, p99_ms, n} over the full run (histogram
        estimate — same shape the reservoir version exported)."""
        out = {}
        with self._lat_lock:
            classes = {cls: ([*h[0]], h[1]) for cls, h in
                       self._latency.items()}
        for cls, (counts, _total) in sorted(classes.items()):
            n = sum(counts)
            if not n:
                continue
            out[cls] = {"n": n,
                        "p50_ms": 1e3 * _hist_percentile(counts, n, 0.50),
                        "p99_ms": 1e3 * _hist_percentile(counts, n, 0.99)}
        return out

    def latency_histogram(self) -> Dict[str, Dict[str, object]]:
        """Raw per-class buckets: {cls: {le_s, counts, sum_s, count}} —
        ``le_s`` upper bounds in seconds, ``counts`` non-cumulative (the
        last entry is the overflow bucket)."""
        with self._lat_lock:
            classes = {cls: ([*h[0]], h[1]) for cls, h in
                       self._latency.items()}
        return {cls: {"le_s": list(LATENCY_BOUNDS_S),
                      "counts": counts,
                      "sum_s": total,
                      "count": sum(counts)}
                for cls, (counts, total) in sorted(classes.items())}

    def padding_efficiency(self) -> float:
        """Valid rows / dispatched rows (1.0 = no padding waste)."""
        dispatched = self.counters.get("rows_dispatched", 0.0)
        if dispatched <= 0:
            return 1.0
        return self.counters.get("rows_valid", 0.0) / dispatched

    def reset(self) -> None:
        self.total_s.clear()
        self.count.clear()
        self.counters.clear()
        with self._gauge_lock:
            self._gauges.clear()
        with self._lat_lock:
            self._latency.clear()

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {stage: {"total_s": self.total_s[stage],
                        "count": self.count[stage],
                        "mean_ms": (1e3 * self.total_s[stage] /
                                    max(self.count[stage], 1))}
                for stage in sorted(self.total_s)}

    def counter_snapshot(self) -> Dict[str, float]:
        return dict(self.counters)

    def gauge_snapshot(self) -> Dict[str, Dict[str, float]]:
        with self._gauge_lock:          # vs concurrent first-time inserts
            items = list(self._gauges.items())
        return {name: {"last": g[0], "max": g[1], "mean": g[2] / max(g[3], 1)}
                for name, g in sorted(items)}


# ---- Prometheus text exposition (format 0.0.4) ------------------------------
def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _fmt(v: float) -> str:
    f = float(v)
    return repr(f) if f != int(f) else str(int(f))


def prometheus_text(timers: StageTimers,
                    extra_gauges: Optional[Dict[str, float]] = None) -> str:
    """Render the full metrics surface as Prometheus text exposition:
    counters as ``serving_<name>_total``, stage timers as
    ``serving_stage_seconds_total`` / ``serving_stage_operations_total``
    labeled by stage, per-worker gauges as labeled families
    (``serving_queue_depth{worker=...}``, ``serving_worker_health``),
    scalar gauges as ``serving_<name>``, and per-class latency as a
    cumulative-bucket ``serving_request_latency_seconds`` histogram."""
    lines: List[str] = []

    counters = timers.counter_snapshot()
    for name in sorted(counters):
        m = f"serving_{_prom_name(name)}_total"
        lines.append(f"# HELP {m} Monotonic serving counter {name}.")
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {_fmt(counters[name])}")

    stages = timers.snapshot()
    if stages:
        lines.append("# HELP serving_stage_seconds_total Wall-clock seconds "
                     "accumulated per pipeline stage.")
        lines.append("# TYPE serving_stage_seconds_total counter")
        for stage in sorted(stages):
            lines.append(f'serving_stage_seconds_total{{stage="{stage}"}} '
                         f'{repr(float(stages[stage]["total_s"]))}')
        lines.append("# HELP serving_stage_operations_total Operations "
                     "timed per pipeline stage.")
        lines.append("# TYPE serving_stage_operations_total counter")
        for stage in sorted(stages):
            lines.append(f'serving_stage_operations_total{{stage="{stage}"}} '
                         f'{_fmt(stages[stage]["count"])}')

    gauges = dict(timers.gauge_snapshot())
    if extra_gauges:
        for name, v in extra_gauges.items():
            gauges.setdefault(name, {"last": float(v)})
    labeled = {"queue_depth": ("serving_queue_depth",
                               "Batcher input-queue backlog per worker."),
               "health": ("serving_worker_health",
                          "Worker health (0 ready / 1 degraded / 2 dead).")}
    emitted_types = set()
    for name in sorted(gauges):
        prefix, _, rest = name.partition(".")
        if rest and prefix in labeled:
            m, help_ = labeled[prefix]
            if m not in emitted_types:
                emitted_types.add(m)
                lines.append(f"# HELP {m} {help_}")
                lines.append(f"# TYPE {m} gauge")
            lines.append(f'{m}{{worker="{rest}"}} '
                         f'{_fmt(gauges[name]["last"])}')
        else:
            m = f"serving_{_prom_name(name)}"
            lines.append(f"# HELP {m} Sampled serving gauge {name} "
                         "(last value).")
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {_fmt(gauges[name]['last'])}")

    hist = timers.latency_histogram()
    if hist:
        m = "serving_request_latency_seconds"
        lines.append(f"# HELP {m} End-to-end request latency per priority "
                     "class (log-scale buckets).")
        lines.append(f"# TYPE {m} histogram")
        for cls, h in hist.items():
            cum = 0
            for le, c in zip(h["le_s"], h["counts"]):
                cum += c
                lines.append(f'{m}_bucket{{class="{cls}",le="{le:.6g}"}} '
                             f'{cum}')
            cum += h["counts"][-1]
            lines.append(f'{m}_bucket{{class="{cls}",le="+Inf"}} {cum}')
            lines.append(f'{m}_sum{{class="{cls}"}} {repr(float(h["sum_s"]))}')
            lines.append(f'{m}_count{{class="{cls}"}} {h["count"]}')

    return "\n".join(lines) + "\n"
