"""Demand forecasting for LiveBench (ROADMAP item j, DESIGN.md §12).

``LiveBench``'s trailing per-member demand EWMA answers "what was the mix
*recently*" — under diurnal traffic that is systematically late: by the
time the EWMA has turned, the wave it should have planned for is already
here, and every replan chases the previous half-cycle.  The forecaster
answers "what will the mix be at the *next replan horizon*": it bins
arrivals per member on the submission path, fits a linear trend (Holt
style) to the recent per-member shares, and extrapolates one lead interval
ahead.  The prediction feeds ``LiveBench.set_forecast`` with a TTL — while
fresh it replaces the EWMA in ``demand_shares()``; if the forecaster stops
publishing, the profile falls back to the EWMA that kept updating
underneath (the handoff tested in tests/test_sim.py).

A linear trend is deliberately the whole model: it needs no period
detection, is right about direction exactly where the EWMA is wrong (on
the wave's flanks, where demand is *moving*), and degrades to the EWMA's
behavior on flat traffic.  Seasonal-naive or spectral models slot in by
overriding :meth:`predict_shares`.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["DemandForecaster"]


class DemandForecaster:
    """Windowed per-member arrival-share estimator with linear-trend
    extrapolation.  Single-threaded by design: in-sim it runs on the event
    loop; live it would run on the controller thread."""

    def __init__(self, M: int, *, bin_s: float = 0.25,
                 history_bins: int = 64, trend_bins: int = 4):
        if M < 1 or bin_s <= 0:
            raise ValueError("need M >= 1 members and bin_s > 0")
        self.M = M
        self.bin_s = float(bin_s)
        self.trend_bins = max(2, int(trend_bins))
        self._hist: "deque[np.ndarray]" = deque(maxlen=history_bins)
        self._cur = np.zeros(M, np.float64)
        self._cur_idx: Optional[int] = None
        self._total = np.zeros(M, np.float64)
        self.observations = 0

    def observe(self, t: float, members: Sequence[int], rows: int) -> None:
        """One offered request at time ``t``: ``rows`` rows for each listed
        member.  ``t`` must be non-decreasing (arrival order)."""
        idx = int(t / self.bin_s)
        if self._cur_idx is None:
            self._cur_idx = idx
        while idx > self._cur_idx:             # close bins, zero-fill gaps
            self._hist.append(self._cur)
            self._cur = np.zeros(self.M, np.float64)
            self._cur_idx += 1
        for m in members:
            self._cur[m] += rows
            self._total[m] += rows
        self.observations += 1

    def _recent_shares(self) -> List[np.ndarray]:
        bins = [b for b in list(self._hist)[-self.trend_bins:]
                if b.sum() > 0]
        return [b / b.sum() for b in bins]

    def predict_shares(self, lead_s: float) -> np.ndarray:
        """Predicted demand shares ``lead_s`` seconds past the last closed
        bin.  With fewer than 2 informative bins this is the cumulative
        observed share (uniform when nothing was observed) — i.e. the
        forecaster never does worse than a long-run average while cold."""
        shares = self._recent_shares()
        if not shares:
            tot = self._total.sum()
            if tot <= 0:
                return np.full(self.M, 1.0 / self.M)
            return self._total / tot
        if len(shares) == 1:
            return shares[0].copy()
        S = np.stack(shares)                   # (k, M) bin shares
        k = S.shape[0]
        x = np.arange(k, dtype=np.float64)     # bin midpoints, bin units
        xm = x.mean()
        denom = ((x - xm) ** 2).sum()
        slope = ((x - xm)[:, None] * (S - S.mean(0))).sum(0) / denom
        # extrapolate from the last bin's midpoint to the lead horizon
        steps = 0.5 + lead_s / self.bin_s
        pred = S[-1] + slope * steps
        pred = np.clip(pred, 1e-3, None)
        return pred / pred.sum()

    def feed(self, live, *, lead_s: float, ttl_s: float) -> np.ndarray:
        """Publish the current prediction into a ``LiveBench``: the replan
        tick calls this right before scoring so the greedy plans against
        where demand is *going*."""
        shares = self.predict_shares(lead_s)
        live.set_forecast(shares, ttl_s=ttl_s)
        return shares
