"""Worker supervision: heartbeat/liveness sweep + crash containment
(DESIGN.md §10).

The paper's failure model (§II.C.2) is all-or-nothing: any worker posting
the {-1, None, None} sentinel fails every in-flight request and shuts the
system down.  The :class:`Supervisor` replaces that with *containment*: it
periodically reads every live worker's :meth:`Worker.health` verdict —

  * **DEAD**: a stage thread crashed (``Worker.crashed`` event) or exited;
  * **DEGRADED**: a stage has been mid-work (ACTIVE heartbeat) longer than
    the watchdog — a stalled XLA call, a wedged lock, an injected stall;

— and quarantines any non-READY instance via
``InferenceSystem.quarantine_instance``, which atomically removes it from
routing and resubmits (or, for a sole instance, forgives) its outstanding
units.  Detection and policy live here; the routing/recovery mutation lives
with the other topology operations on the system.

Two detection paths share the same sweep:

  * the **fast path**: a dying stage thread calls ``on_worker_crash`` (the
    worker's ``on_crash`` hook) which wakes the sweep immediately — crash
    containment latency is scheduling noise, not the sweep interval;
  * the **slow path**: the interval tick catches stalls (a stalled thread
    never calls anything) and any crash whose hook failed.

Counters (ride ``serving_counters()`` / ``GET /metrics``):
``worker_crashes``, ``stalls_detected``, ``quarantines``,
``segments_replayed`` (the last two from ``quarantine_instance``).
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.serving.worker import HEALTH_DEAD, HEALTH_DEGRADED, Worker


class Supervisor:
    def __init__(self, system, *, watchdog_s: float = 5.0,
                 interval_s: float = 0.05, retry_budget: int = 2):
        self.system = system
        self.watchdog_s = watchdog_s
        self.interval_s = interval_s
        self.retry_budget = retry_budget
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, name="supervisor",
                                        daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout)

    # ---- fast path: called on the dying stage thread -------------------------
    def on_worker_crash(self, worker: Worker, exc: BaseException) -> None:
        """The worker's ``on_crash`` hook.  Runs on the stage thread that is
        about to die, so it only counts and wakes the sweep — quarantine
        (which takes the submit lock and may fail requests) happens on the
        supervisor thread."""
        self.system.timers.inc("worker_crashes")
        self._wake.set()

    # ---- the sweep -----------------------------------------------------------
    def _run(self) -> None:
        while True:
            self._wake.wait(self.interval_s)
            self._wake.clear()
            if self._stop.is_set():
                return
            try:
                self.sweep()
            except Exception:
                # a sweep failure must never kill supervision; the next
                # tick retries with fresh state
                self.system.timers.inc("supervisor_errors")

    def sweep(self) -> int:
        """One detection pass: quarantine every non-READY live worker.
        Returns the number quarantined (exposed for tests)."""
        system = self.system
        with system._submit_lock:
            workers = list(system.workers)
        hit = 0
        for w in workers:
            h = w.health(self.watchdog_s)
            if h == HEALTH_DEAD or h == HEALTH_DEGRADED:
                if h == HEALTH_DEGRADED:
                    system.timers.inc("stalls_detected")
                    tr = getattr(system, "tracer", None)
                    if tr is not None:
                        # freeze the flight recorder before quarantine tears
                        # the stalled worker's state down (DESIGN.md §13)
                        tr.anomaly("watchdog_stall", w.worker_id)
                system.quarantine_instance(w, retry_budget=self.retry_budget)
                hit += 1
        return hit
