from repro.models.transformer import (Model, decode_step, forward, init_params,
                                      prefill)
from repro.models.cache import init_cache

__all__ = ["Model", "forward", "prefill", "decode_step", "init_params", "init_cache"]
