"""Serving hot-path A/B: seed path vs pipelined engine vs coalescing scheduler.

Overhead-dominated regime (paper §IV.A): fake workers sharing ONE device make
prediction cost ~nothing, isolating the serving machinery — batching, queues,
transfers, combination.  Scenarios:

  * ``seed``        per-member messages (``device_combine=False``), one
                    request in flight — the vendored seed behavior;
  * ``pipelined``   the PR-1 engine: device-resident partial combine +
                    multi-request window, but batches formed strictly within
                    one (request, segment) pair (``coalesce=False``);
  * ``coalesced``   the PR-2 coalescing scheduler: cross-request continuous
                    batching with span scatter descriptors;
  * ``many_small``  the north-star workload — many concurrent requests each
                    far smaller than a segment, run with REAL (tiny) models
                    so padding waste costs real compute.  Compares the PR-1
                    engine against the coalescing scheduler and reports
                    padding efficiency (valid rows / dispatched rows);
  * ``mixed_priority``  the SLO workload (ISSUEs 3/5, ROADMAP items a/e/k):
                    a bulk scan saturates the admission queues while small
                    latency-sensitive requests trickle in.  Runs the same
                    trace twice — all-normal (strict FIFO, the PR-2
                    behavior) vs the small requests at ``priority="high"``
                    — and reports per-class p50/p99 latency plus total
                    segments/sec.  With the chunk-granular dispatch queue
                    (ISSUE 5) a high-priority chunk jumps bulk chunks
                    already *flushed* into the predictor pipeline, so the
                    p50 (not just the p99 tail) approaches the queue-jump
                    ideal: ``hp_p50_improvement`` gates it;
  * ``skewed_load``  the elasticity workload (ISSUE 4, ROADMAP items c/g):
                    one hot member under a 4:1 per-member request skew,
                    served by a slow batch-8 instance (co-located with the
                    cold member) and a fast batch-128 data-parallel sibling.
                    Fake workers with *simulated device time*
                    (``fake_delay_us`` per compiled batch — the sleep
                    releases the GIL, so worker parallelism and service
                    rates are deterministic on any host) isolate the
                    scheduling effect: static striping leaves half the hot
                    member's segments queued behind the slow instance while
                    the fast sibling idles.  Runs the identical trace with
                    the work-stealing fast path off vs on and reports the
                    throughput ratio;
  * ``overload_brownout``  the overload workload (ISSUE 7, DESIGN.md §11):
                    a cheap and a heavy member on simulated device time,
                    requests paced at ~3x the heavy member's service rate.
                    Runs the identical trace twice — plain system (queues
                    grow without bound, every request waits behind the
                    heavy backlog) vs the brownout controller + admission
                    byte budget (pressure crosses the hysteresis band,
                    in-flight requests are demoted to the cheap tier and
                    new ones planned against it; anything past the budget
                    is shed with a *typed* ``Overloaded`` + Retry-After).
                    Reports ``completed_or_shed_ratio`` (every request
                    either resolves with a quality-stamped result or a
                    typed rejection — nothing hangs or dies untyped) and
                    ``brownout_p99_improvement`` (normal-class p99 off/on);
  * ``fault_recovery``  the chaos workload (ISSUE 6, DESIGN.md §10): two
                    data-parallel siblings of a hot member on simulated
                    device time, a ``FaultPlan`` killing one sibling's
                    predictor a few chunks into the trace.  The supervisor
                    must quarantine the instance and replay its outstanding
                    units on the survivor: the scenario reports the
                    completed-at-full-quality ratio, the crash-to-replay
                    recovery latency, and a ``recovery_ok`` verdict.

Acceptance (ISSUE 2): many_small coalesced >= 1.5x the PR-1 engine
segments/sec; single large-request throughput within 5% (the
``large_request_ratio``); padding efficiency reported in BENCH_serving.json.
Acceptance (ISSUE 3): high-priority p99 improves >= 3x over FIFO while total
segments/sec stays within 10% (``mixed_priority.hp_p99_improvement`` /
``.throughput_ratio`` in BENCH_serving.json, gated by check_regression.py).
Acceptance (ISSUE 4): work stealing >= 1.3x throughput under the 4:1 skew
(``skewed_load.steal_throughput_ratio``, gated by check_regression.py).
Acceptance (ISSUE 5): with the chunk-granular dispatch queue, high-priority
p50 improves >= 4x over strict FIFO (``mixed_priority.hp_p50_improvement``)
while hp_p99_improvement and throughput_ratio hold their floors.
Acceptance (ISSUE 6): killing one sibling mid-trace loses zero requests
(``fault_recovery.completed_ratio`` == 1.0 at full quality) and recovery
lands within a second (``fault_recovery.recovery_ok`` == 1.0), both gated
by check_regression.py.
  * ``sim_fidelity``  the calibration workload (ISSUE 8, DESIGN.md §12):
                    a real run on simulated device time records its own
                    request trace (``system.trace_recorder``) and feeds a
                    LiveBench; the trace then replays in the discrete-event
                    simulator (``repro.serving.sim``) against a
                    ``ServiceModel`` fitted from that LiveBench snapshot.
                    Reports sim/real throughput and p99 ratios plus a
                    ``fidelity_ok`` verdict (both within 20%).

Every scenario draws its inputs from ``--seed`` (recorded as ``rng_seed``
in BENCH_serving.json); ``--scenario NAME`` (repeatable) runs a subset —
the serving-smoke CI job uses it to stay within its time budget.
``--replay-trace PATH`` replays a trace recorded with
``launch/serve.py --record-trace`` against a fake-device system instead.

Acceptance (ISSUE 7): under 3x saturation every request completes or is
typed-rejected (``overload_brownout.completed_or_shed_ratio`` == 1.0) and
brownout improves normal-class p99 >= 2x over the uncontrolled run
(``overload_brownout.brownout_p99_improvement``), both gated by
check_regression.py.
Acceptance (ISSUE 8): the simulator reproduces the real mixed-delay run's
throughput and pooled p99 within 20% (``sim_fidelity.fidelity_ok``, gated
by check_regression.py).
  * ``quantized_members``  the quantization workload (ISSUE 10, DESIGN.md
                    §14): two legs.  The *speedup* leg runs the heavy-member
                    trace twice on simulated device time — fp32 vs int8 —
                    with the int8 leg's ``fake_delay_us`` scaled by the
                    dtype-aware ``AnalyticBench`` memory-term ratio (weight
                    streaming dominates heavy members, so narrow params cut
                    per-batch device time ~3x; the serving machinery around
                    it is real either way).  The *parity* leg runs REAL tiny
                    models through the fused dequant-weight-accumulate
                    epilogue (``combine="pallas"``, int8 members) against
                    the fp32 reference and checks the combined output and a
                    member-subset output stay within int8 tolerance.
Acceptance (ISSUE 10): quantized members >= 1.3x segments/sec on the
heavy-member scenario (``quantized_members.quant_speedup``) with combine
output within tolerance of the fp32 reference
(``quantized_members.quant_parity_ok``), both gated by check_regression.py.
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks.seed_baseline import SeedSystem
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving import segments as seg
from repro.serving.segments import PredictOptions

GiB = 1024 ** 3


def _pctl(values, q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q))


def _measure(system, X, requests: int, pipelined: bool) -> dict:
    n_segments = seg.num_segments(X.shape[0], system.segment_size)
    system.predict(X)                      # warm
    if pipelined:
        system.timers.reset()
    msg0 = system.accumulator.data_messages
    t0 = time.perf_counter()
    if pipelined:                          # overlap through the window
        handles = [system.predict_async(X) for _ in range(requests)]
        for h in handles:
            h.result(600.0)
    else:                                  # seed path: requests serialize
        for _ in range(requests):
            system.predict(X)
    dt = time.perf_counter() - t0
    out = {
        "requests": requests,
        "segments_per_request": n_segments,
        "seconds": dt,
        "segments_per_sec": requests * n_segments / dt,
        "samples_per_sec": requests * X.shape[0] / dt,
        "messages_per_request":
            (system.accumulator.data_messages - msg0) / requests,
        "stage_timings": system.stage_timings() if pipelined else {},
    }
    if pipelined:
        out["counters"] = system.serving_counters()
        out["padding_efficiency"] = out["counters"]["padding_efficiency"]
    return out


def _measure_many_small(system, Xs, rounds: int) -> dict:
    """Submit ``rounds`` waves of the small concurrent requests through the
    in-flight window and measure aggregate segments/sec + padding."""
    for X in Xs[:4]:                       # warm the small pow2 bucket shapes
        system.predict(X)
    system.predict(np.concatenate(Xs, axis=0)[:32])   # warm the full batch
    system.timers.reset()
    msg0 = system.accumulator.data_messages
    n_requests = rounds * len(Xs)
    n_segments = sum(seg.num_segments(x.shape[0], system.segment_size)
                     for x in Xs) * rounds
    n_samples = sum(x.shape[0] for x in Xs) * rounds
    t0 = time.perf_counter()
    handles = []
    for _ in range(rounds):
        handles.extend(system.predict_async(x) for x in Xs)
    for h in handles:
        h.result(600.0)
    dt = time.perf_counter() - t0
    counters = system.serving_counters()
    return {
        "requests": n_requests,
        "segments": n_segments,
        "seconds": dt,
        "segments_per_sec": n_segments / dt,
        "samples_per_sec": n_samples / dt,
        "messages_per_request":
            (system.accumulator.data_messages - msg0) / n_requests,
        "padding_efficiency": counters["padding_efficiency"],
        "counters": counters,
        "queue_depth": {k: v for k, v in system.serving_gauges().items()
                        if k.startswith("queue_depth.")},
        "stage_timings": system.stage_timings(),
    }


def _measure_mixed_priority(system, bulk_X, small_Xs, rounds: int,
                            high_priority: bool) -> dict:
    """Sustained-load SLO trace: every bulk round is submitted up front
    (normal priority) so the backlog persists for the whole window, and the
    small requests are *paced* — submitted at fixed wall-clock intervals
    from short-lived threads while the backlog drains.  Under strict FIFO a
    small request's latency is the remaining bulk backlog at its submit
    time (seconds); with priority admission + the chunk-granular dispatch
    queue it is the non-preemptible head (the chunk on the device plus the
    dispatch-ahead window — tens of ms).  The pace is calibrated from a
    measured solo bulk scan so the small trace spans ~60% of the backlog
    window on any host speed."""
    import threading

    opts = PredictOptions(priority="high" if high_priority else "normal")
    system.predict(bulk_X[:system.segment_size])     # warm shapes
    tb = time.perf_counter()
    system.predict(bulk_X)                           # calibrate drain time
    bulk_s = time.perf_counter() - tb
    for x in small_Xs[:2]:
        system.predict(x, options=opts)
    seg_sz = system.segment_size
    n_segments = rounds * (seg.num_segments(bulk_X.shape[0], seg_sz) +
                           sum(seg.num_segments(x.shape[0], seg_sz)
                               for x in small_Xs))
    n_smalls = rounds * len(small_Xs)
    pace = bulk_s * rounds * 0.6 / n_smalls
    lat_high, lat_bulk = [], []
    lock = threading.Lock()

    def one_small(x):
        t1 = time.perf_counter()
        system.predict(x, options=opts, timeout=600.0)
        with lock:
            lat_high.append(time.perf_counter() - t1)

    t0 = time.perf_counter()
    bulk_handles = [system.predict_async(bulk_X) for _ in range(rounds)]
    threads = []
    for i in range(n_smalls):
        time.sleep(pace)
        t = threading.Thread(target=one_small,
                             args=(small_Xs[i % len(small_Xs)],))
        t.start()
        threads.append(t)
    for t in threads:
        t.join()
    for h in bulk_handles:
        h.result(600.0)
        lat_bulk.append(time.perf_counter() - t0)
    dt = time.perf_counter() - t0
    return {
        "rounds": rounds,
        "seconds": dt,
        "segments_per_sec": n_segments / dt,
        "high": {"requests": len(lat_high),
                 "p50_ms": 1e3 * _pctl(lat_high, 50),
                 "p99_ms": 1e3 * _pctl(lat_high, 99)},
        "bulk": {"requests": len(lat_bulk),
                 "p50_ms": 1e3 * _pctl(lat_bulk, 50),
                 "p99_ms": 1e3 * _pctl(lat_bulk, 99)},
    }


def _measure_skewed(cfgs, params, devs, seq: int, requests: int,
                    fake_delay_us: int, steal: bool, seed: int = 0) -> dict:
    """One skewed_load pass: 4:1 per-member request skew against a hot
    member with heterogeneous data-parallel instances (d0@8 slow, d1@128
    fast); the cold member rides the slow device.  With ``steal`` the
    reconfiguration controller's fast path re-routes the slow instance's
    backlog (expected-row maps move between the device combiners)."""
    from repro.serving.control import ReconfigController
    from repro.serving.system import InferenceSystem

    seg_sz = 128
    A = np.array([[8, 128], [128, 0]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    srng = np.random.default_rng([seed, 4])
    member_lists = [[0] if i % 5 < 4 else [1] for i in range(requests)]
    Xs = [srng.integers(0, 512, (seg_sz, seq)).astype(np.int32)
          for _ in member_lists]
    with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                         max_seq=seq, fake=True,
                         fake_delay_us=fake_delay_us,
                         max_in_flight=requests, max_wait_us=200) as system:
        controller = ReconfigController(
            system, replan=False, steal=steal, steal_interval_s=0.001,
            steal_threshold=1, steal_max=64)
        controller.start()
        for _ in range(3):                 # warm the live latency profile
            system.predict(Xs[0], members=[0])
            system.predict(Xs[1], members=[1])
        t0 = time.perf_counter()
        handles = [system.predict_async(x, members=m)
                   for x, m in zip(Xs, member_lists)]
        for h in handles:
            h.result(600.0)
        dt = time.perf_counter() - t0
        stolen = controller.counters["stolen"]
    return {
        "requests": requests,
        "seconds": dt,
        "segments_per_sec": requests / dt,   # single-segment requests
        "stolen_descriptors": stolen,
    }


def _measure_fault_recovery(cfgs, params, seq: int, requests: int,
                            fake_delay_us: int, seed: int = 0) -> dict:
    """One chaos pass (ISSUE 6): member 0 runs two equal data-parallel
    siblings (d0/d1); a FaultPlan kills the d1 sibling's predictor after 3
    chunks.  Simulated device time makes the service rates — and thus the
    crash position in the trace — deterministic on any host.  A 1 ms
    watcher thread timestamps the crash (``worker_crashes`` counter, set on
    the dying thread) and the recovery (``segments_replayed``, set when the
    supervisor resubmits the dead worker's outstanding units), so
    ``recovery_s`` is the supervisor's crash-to-replay latency."""
    import threading

    from repro.serving.faults import FaultPlan, FaultSpec
    from repro.serving.system import InferenceSystem

    seg_sz = 64
    devs = host_cpus(2, memory_bytes=8 * GiB)
    A = np.array([[seg_sz, seg_sz], [seg_sz, 0]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    fp = FaultPlan(FaultSpec(stage="predictor", kind="raise", after=3,
                             worker="w1.0"))
    srng = np.random.default_rng([seed, 6])
    Xs = [srng.integers(0, 512, (seg_sz, seq)).astype(np.int32)
          for _ in range(requests)]
    marks: dict = {}
    with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                         max_seq=seq, fake=True,
                         fake_delay_us=fake_delay_us,
                         max_in_flight=requests, supervise=True,
                         supervise_interval_s=0.01,
                         fault_plan=fp) as system:

        def watch():
            while not marks.get("stop"):
                c = system.serving_counters()
                if "t_crash" not in marks and c.get("worker_crashes", 0):
                    marks["t_crash"] = time.perf_counter()
                if c.get("segments_replayed", 0):
                    marks.setdefault("t_crash", time.perf_counter())
                    marks["t_recovered"] = time.perf_counter()
                    return
                time.sleep(0.001)

        wt = threading.Thread(target=watch)
        wt.start()
        t0 = time.perf_counter()
        handles = [system.predict_async(x) for x in Xs]
        full_quality = 0
        for h in handles:
            y = h.result(600.0)           # raises on any lost request
            if y.shape[0] == seg_sz and h.quality == 1.0:
                full_quality += 1
        dt = time.perf_counter() - t0
        marks["stop"] = True
        wt.join(5.0)
        counters = system.serving_counters()
    recovery_s = (marks["t_recovered"] - marks["t_crash"]
                  if "t_recovered" in marks else float("inf"))
    completed_ratio = full_quality / requests
    recovery_ok = float(completed_ratio == 1.0 and
                        counters.get("quarantines", 0) == 1 and
                        recovery_s <= 1.0)
    return {
        "requests": requests,
        "seconds": dt,
        "completed_ratio": completed_ratio,
        "recovery_s": recovery_s,
        "recovery_ok": recovery_ok,
        "segments_replayed": counters.get("segments_replayed", 0),
        "worker_crashes": counters.get("worker_crashes", 0),
    }


def _measure_overload_brownout(cfgs, params, seq: int, requests: int,
                               pace_s: float, cheap_delay_us: int,
                               heavy_delay_us: int, brownout: bool,
                               seed: int = 0) -> dict:
    """One overload pass (ISSUE 7): member 0 cheap, member 1 heavy (each on
    its own simulated device), requests paced at ~3x the heavy member's
    service rate.  With ``brownout`` a :class:`BrownoutController` (explicit
    two-level tier table: full ensemble, then the cheap member alone) and an
    admission byte budget are attached; without, the plain system queues
    without bound.  Per-request latency comes from the system's own
    normal-class snapshot, so both passes measure identically."""
    from repro.serving.admission import AdmissionBudget
    from repro.serving.segments import Overloaded
    from repro.serving.system import InferenceSystem

    seg_sz = 64
    devs = host_cpus(2, memory_bytes=8 * GiB)
    A = np.array([[seg_sz, 0], [0, seg_sz]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    srng = np.random.default_rng([seed, 7])
    Xs = [srng.integers(0, 512, (seg_sz, seq)).astype(np.int32)
          for _ in range(requests)]
    budget = (AdmissionBudget(max_bytes=40 * seg_sz * seq * 4)
              if brownout else None)
    with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                         max_seq=seq, fake=True,
                         fake_delay_us=cheap_delay_us,
                         max_in_flight=requests,
                         admission_budget=budget) as system:
        for w in system.instances(1):      # heterogeneous member costs
            w.fake_delay_us = heavy_delay_us
        ctl = None
        if brownout:
            from repro.serving.control import BrownoutController
            ctl = BrownoutController(
                system, tiers=[(0, 1), (0,)], high=1.0, low=0.2,
                up_ticks=2, down_ticks=1000, interval_s=0.002,
                depth_ref=8.0).start()
        handles, shed = [], 0
        t0 = time.perf_counter()
        for x in Xs:
            try:
                handles.append(system.predict_async(x))
            except Overloaded:
                shed += 1                   # typed, fail-fast, retryable
            time.sleep(pace_s)
        completed = 0
        qualities = []
        for h in handles:
            y = h.result(600.0)             # raises on any lost request
            if y.shape[0] == seg_sz:
                completed += 1
                qualities.append(float(h.quality))
        dt = time.perf_counter() - t0
        lat = system.latency_snapshot().get("normal", {})
        counters = system.serving_counters()
        out = {
            "requests": requests,
            "seconds": dt,
            "completed": completed,
            "shed": shed,
            "completed_or_shed_ratio": (completed + shed) / requests,
            "p50_ms": lat.get("p50_ms", 0.0),
            "p99_ms": lat.get("p99_ms", 0.0),
            "mean_quality": (float(np.mean(qualities))
                             if qualities else 0.0),
            "requests_demoted": counters.get("requests_demoted", 0),
            "admission_rejections": counters.get("admission_rejections", 0),
            "brownout_level": ctl.level if ctl is not None else 0,
            "brownout_transitions": ctl.transitions if ctl is not None else 0,
        }
    return out


def _measure_tracing_overhead(cfgs, params, alloc, X, seq: int,
                              requests: int) -> dict:
    """Tracing-on vs tracing-off on the fake-worker hot path (ISSUE 9).

    Same configuration as the core coalesced scenario.  ONE system,
    toggling the runtime ``tracer.enabled`` flag, so both modes share
    threads, compiled shapes and allocator state.  Machine throughput
    drifts at the few-percent scale over seconds (shared hosts), which
    is the same magnitude as the budget being gated, so the estimator
    has to be burst-robust: waves alternate off/on (order flipped every
    pair, so slow drift hits both modes equally), each estimate is the
    ratio of 10%-TRIMMED per-mode sums (a burst landing on a few waves
    is discarded instead of averaged in), and the reported ratio is the
    median of ``reps`` independent estimates.  ``overhead_ratio`` is
    the span layer's whole cost with the flight recorder enabled;
    ``overhead_ok`` asserts the <= 5% budget (check_regression.py gates
    it at 1.0)."""
    from repro.serving.system import InferenceSystem

    n_segments = seg.num_segments(X.shape[0], 128)
    waves = max(2, requests // 4)          # concurrent requests per wave
    reps, alternations = 3, 40
    times = {"off": [], "on": []}
    with InferenceSystem(cfgs, params, alloc, segment_size=128,
                         max_seq=seq, fake=True, device_combine=True,
                         max_in_flight=4, coalesce=True,
                         tracing=False) as system:

        def wave() -> float:
            t0 = time.perf_counter()
            handles = [system.predict_async(X) for _ in range(waves)]
            for h in handles:
                h.result(600.0)
            return time.perf_counter() - t0

        def trimmed_sum(xs: list) -> float:
            s = sorted(xs)
            k = len(s) // 10
            return sum(s[k:len(s) - k])

        def estimate() -> float:
            t = {"off": [], "on": []}
            for i in range(alternations):
                order = ("off", "on") if i % 2 == 0 else ("on", "off")
                for mode in order:
                    system.tracer.enabled = mode == "on"
                    dt = wave()
                    t[mode].append(dt)
                    times[mode].append(dt)
            return trimmed_sum(t["on"]) / trimmed_sum(t["off"])

        for _ in range(2):                 # warm threads + slot rings
            wave()
        ratios = [estimate() for _ in range(reps)]
        trace_events = sum(len(evs)
                           for evs in system.tracer.tracks().values())
    ratio = statistics.median(ratios)
    per_wave = waves * n_segments
    return {"off_segments_per_sec":
            per_wave / statistics.median(times["off"]),
            "on_segments_per_sec":
            per_wave / statistics.median(times["on"]),
            "estimate_ratios": ratios,
            "overhead_ratio": ratio,
            "overhead_ok": float(ratio <= 1.05),
            "trace_events": trace_events}


def _measure_sim_fidelity(cfgs, params, seq: int, requests: int,
                          pace_s: float, cheap_delay_us: int,
                          heavy_delay_us: int, seed: int = 0) -> dict:
    """Calibration harness for the discrete-event simulator (DESIGN.md §12).

    One real pass on simulated device time (two members with heterogeneous
    ``fake_delay_us``, each on its own device, all requests exactly one
    compiled batch so LiveBench attributes every observation to the bucket
    it ran in), recording the offered trace and fitting a
    :class:`ServiceModel` from the LiveBench EWMA the run itself produced.
    The recorded trace then replays in the simulator on the same
    allocation, and the pooled p99 + request throughput must land within
    20% of the real run (``fidelity_ok``, gated by check_regression.py) —
    the evidence that conclusions drawn in-sim (forecast-fed replanning,
    dispatch-ahead tuning, EDF) transfer to the live engine.

    The pace runs the heavy member slightly *past* saturation on purpose:
    the p99 tail is then dominated by deterministic backlog growth — which
    the simulator reproduces exactly from the recorded arrival times —
    rather than by host scheduling jitter, which no deterministic model
    reproduces.  (At comfortable utilization the real tail is pure sleep/
    thread jitter and the comparison measures the host, not the sim.)"""
    import threading

    from repro.serving.control import LiveBench
    from repro.serving.sim import ServiceModel, SimSystem
    from repro.serving.system import InferenceSystem
    from repro.serving.trace import TraceRecorder

    seg_sz = 64
    devs = host_cpus(2, memory_bytes=8 * GiB)
    A = np.array([[seg_sz, 0], [0, seg_sz]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    srng = np.random.default_rng([seed, 8])
    Xs = [srng.integers(0, 512, (seg_sz, seq)).astype(np.int32)
          for _ in range(requests)]
    live = LiveBench(cfgs)
    rec = TraceRecorder()
    lat: list = []
    lock = threading.Lock()
    with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                         max_seq=seq, fake=True,
                         fake_delay_us=cheap_delay_us,
                         max_in_flight=requests, dispatch_ahead=4,
                         max_wait_us=200) as system:
        for w in system.instances(1):      # heterogeneous member costs
            w.fake_delay_us = heavy_delay_us
        system.set_profiler(live)
        for m in (0, 1):                   # warm shapes + the EWMA prior
            system.predict(Xs[0], members=[m])
            system.predict(Xs[1], members=[m])
        system.trace_recorder = rec        # record only the measured trace

        def waiter(h, t1):
            h.result(600.0)
            with lock:
                lat.append(time.perf_counter() - t1)

        threads = []
        t0 = time.perf_counter()
        for i, x in enumerate(Xs):
            h = system.predict_async(x, members=[i % 2])
            th = threading.Thread(target=waiter,
                                  args=(h, time.perf_counter()))
            th.start()
            threads.append(th)
            time.sleep(pace_s)
        for th in threads:
            th.join()
        real_dt = time.perf_counter() - t0
        snapshot = live.snapshot()

    real = {"requests": requests, "seconds": real_dt,
            "req_per_s": requests / real_dt,
            "p50_ms": 1e3 * _pctl(lat, 50), "p99_ms": 1e3 * _pctl(lat, 99)}

    svc = ServiceModel.from_livebench(snapshot)
    sim = SimSystem.from_alloc(alloc, svc, segment_size=seg_sz,
                               dispatch_ahead=4, max_wait_us=200)
    trace = rec.events()
    sim.run(trace)
    r = sim.results()
    sim_out = {"requests": len(trace), "req_per_s": r["throughput_req_per_s"],
               "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
               "completed": r["completed"], "failed": r["failed"]}
    thr_ratio = sim_out["req_per_s"] / real["req_per_s"]
    p99_ratio = sim_out["p99_ms"] / max(real["p99_ms"], 1e-9)
    tol = 0.20
    fidelity_ok = float(abs(thr_ratio - 1.0) <= tol and
                        abs(p99_ratio - 1.0) <= tol and
                        r["completed"] == len(trace))
    return {"real": real, "sim": sim_out, "trace_requests": len(trace),
            "throughput_ratio": thr_ratio, "p99_ratio": p99_ratio,
            "tolerance": tol, "fidelity_ok": fidelity_ok}


def replay_trace(path: str, *, seq: int = 16, workers: int = 2,
                 speed: float = 1.0, csv: bool = True) -> dict:
    """Replay a recorded request trace (``--record-trace`` /
    ``system.trace_recorder``) against a real fake-device system,
    preserving per-request priority, deadline and member subsets and
    pacing submissions by the recorded inter-arrival gaps (divided by
    ``speed``).  The offline twin of the simulator's ``sim.run(trace)``."""
    import threading

    import jax
    import repro.models as M
    from repro.serving.system import InferenceSystem
    from repro.serving.trace import load_trace

    events = load_trace(path)
    cfgs = ensemble("ENS4")[:workers]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    devs = host_cpus(1, memory_bytes=8 * GiB)
    A = np.full((1, len(cfgs)), 64)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    srng = np.random.default_rng(0)
    lat: list = []
    lock = threading.Lock()
    failed = 0
    with InferenceSystem(cfgs, params, alloc, segment_size=64, max_seq=seq,
                         fake=True, max_in_flight=max(64, len(events)),
                         max_wait_us=500) as system:
        system.predict(srng.integers(0, 512, (8, seq)).astype(np.int32))

        def waiter(h, t1):
            nonlocal failed
            try:
                h.result(600.0)
            except Exception:
                with lock:
                    failed += 1
                return
            with lock:
                lat.append(time.perf_counter() - t1)

        threads = []
        t0 = time.perf_counter()
        for ev in events:
            target = t0 + ev.t / speed
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            X = srng.integers(0, 512, (ev.rows, seq)).astype(np.int32)
            members = None if ev.members is None else list(ev.members)
            opts = PredictOptions(priority=ev.priority,
                                  deadline_ms=ev.deadline_ms)
            try:
                h = system.predict_async(X, members=members, options=opts)
            except Exception:
                with lock:
                    failed += 1
                continue
            th = threading.Thread(target=waiter,
                                  args=(h, time.perf_counter()))
            th.start()
            threads.append(th)
        for th in threads:
            th.join()
        dt = time.perf_counter() - t0
    out = {"trace": path, "requests": len(events), "speed": speed,
           "seconds": dt, "completed": len(lat), "failed": failed,
           "req_per_s": len(lat) / dt,
           "p50_ms": 1e3 * _pctl(lat, 50) if lat else 0.0,
           "p99_ms": 1e3 * _pctl(lat, 99) if lat else 0.0}
    if csv:
        print(f"serving_hotpath:replay.req_per_s,{out['req_per_s']:.1f},")
        print(f"serving_hotpath:replay.p50/p99_ms,{out['p50_ms']:.1f},"
              f"{out['p99_ms']:.1f}")
    return out


def _measure_quantized_members(cfgs, params, seq: int, requests: int,
                               heavy_delay_us: int, seed: int = 0) -> dict:
    """One quantization pass (ISSUE 10, DESIGN.md §14).

    Speedup leg: both members heavy (``heavy_delay_us`` simulated device
    time per compiled batch) on one shared device; the int8 run scales the
    delay by the dtype-aware ``AnalyticBench`` *memory-term* ratio — heavy
    members are weight-streaming-bound on accelerators, and this is the
    same term the allocator prices quantized members with — so the measured
    segments/sec ratio isolates what narrow params buy while queues,
    staging, and combine run for real.

    Parity leg: real tiny models, fp32 system vs int8 system with the
    device-resident pallas combine (the fused dequant-weight-accumulate
    epilogue), full ensemble and a member subset; ``quant_parity_ok``
    verdicts both within int8 tolerance.
    """
    from repro.core.bench import AnalyticBench
    from repro.kernels import quant as kq
    from repro.serving.system import InferenceSystem

    seg_sz = 64
    devs = host_cpus(1, memory_bytes=8 * GiB)
    A = np.array([[seg_sz, seg_sz]])
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    srng = np.random.default_rng([seed, 10])
    Xs = [srng.integers(0, 512, (seg_sz, seq)).astype(np.int32)
          for _ in range(requests)]

    bench = AnalyticBench(cfgs, seq=seq)
    ratio = (sum(bench.bytes_moved(c, seg_sz, "int8") for c in cfgs) /
             sum(bench.bytes_moved(c, seg_sz) for c in cfgs))
    out: dict = {"roofline_ratio": ratio}
    for mode, dts, delay in (
            ("fp32", None, heavy_delay_us),
            ("int8", ["int8"] * len(cfgs), int(heavy_delay_us * ratio))):
        with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                             max_seq=seq, fake=True, fake_delay_us=delay,
                             max_in_flight=requests,
                             member_dtypes=dts) as system:
            t0 = time.perf_counter()
            handles = [system.predict_async(x) for x in Xs]
            for h in handles:
                h.result(600.0)
            dt = time.perf_counter() - t0
        out[mode] = {"requests": requests, "seconds": dt,
                     "fake_delay_us": delay,
                     "segments_per_sec": requests / dt}
    out["quant_speedup"] = (out["int8"]["segments_per_sec"] /
                            out["fp32"]["segments_per_sec"])

    # ---- parity leg: real tiny models through the fused epilogue ----------
    Xp = srng.integers(0, 512, (2 * seg_sz, seq)).astype(np.int32)

    def real_run(dts):
        with InferenceSystem(cfgs, params, alloc, segment_size=seg_sz,
                             max_seq=seq, combine="pallas",
                             member_dtypes=dts) as system:
            y_full = system.predict(Xp)
            y_sub = system.predict(Xp[:seg_sz], members=[0])
            staged = sum(w.timers.counters.get("h2d_staged", 0)
                         for w in system.workers)
        return y_full, y_sub, staged

    ref_full, ref_sub, _ = real_run(None)
    q_full, q_sub, staged = real_run(["int8"] * len(cfgs))

    def rel_err(y, yref):
        return float(np.abs(y - yref).max() /
                     max(np.abs(yref).max(), 1e-6))

    out["parity_rel_err"] = rel_err(q_full, ref_full)
    out["subset_rel_err"] = rel_err(q_sub, ref_sub)
    out["h2d_staged"] = int(staged)
    out["quant_parity_ok"] = float(out["parity_rel_err"] < 0.05
                                   and out["subset_rel_err"] < 0.05)
    return out


SCENARIOS = ("core", "many_small", "mixed_priority", "skewed_load",
             "fault_recovery", "overload_brownout", "sim_fidelity",
             "tracing_overhead", "quantized_members")


def run(csv=True, n_samples=2048, seq=16, requests=24, workers=4,
        small_concurrency=48, small_rounds=8, small_max_wait_us=2000,
        mixed_rounds=3, mixed_smalls=8, mixed_bulk=1024,
        skew_requests=40, skew_delay_us=4000,
        fault_requests=32, fault_delay_us=4000,
        overload_requests=120, overload_pace_s=0.00133,
        overload_cheap_us=400, overload_heavy_us=4000,
        fidelity_requests=150, fidelity_pace_s=0.008,
        fidelity_cheap_us=10000, fidelity_heavy_us=20000,
        quant_requests=32, quant_delay_us=8000,
        seed=0, scenarios=None):
    import jax
    import repro.models as M
    from repro.serving.system import InferenceSystem

    sel = set(SCENARIOS) if not scenarios else set(scenarios)
    unknown = sel - set(SCENARIOS)
    if unknown:
        raise ValueError(f"unknown scenarios {sorted(unknown)} "
                         f"(expected a subset of {list(SCENARIOS)})")

    cfgs = ensemble("ENS4")[:workers]
    rng = jax.random.PRNGKey(seed)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    devs = host_cpus(1, memory_bytes=8 * GiB)       # ONE shared device
    A = np.full((1, len(cfgs)), 8)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    X = np.random.default_rng([seed, 0]).integers(
        0, 512, (n_samples, seq)).astype(np.int32)
    small_cfgs = cfgs[:2]
    small_params = params[:2]
    A_small = np.full((1, len(small_cfgs)), 16)
    alloc_small = AllocationMatrix(devs, [c.name for c in small_cfgs], A_small)

    results = {"rng_seed": seed, "scenarios": sorted(sel)}
    if "core" in sel:
        with SeedSystem(cfgs, alloc, max_seq=seq) as system:
            results["seed"] = _measure(system, X, requests, pipelined=False)
        for name, coalesce in (("pipelined", False), ("coalesced", True)):
            with InferenceSystem(cfgs, params, alloc, segment_size=128,
                                 max_seq=seq, fake=True, device_combine=True,
                                 max_in_flight=4, coalesce=coalesce) as system:
                results[name] = _measure(system, X, requests, pipelined=True)

        results["speedup"] = (results["pipelined"]["segments_per_sec"] /
                              results["seed"]["segments_per_sec"])
        # single large requests: coalescing must not regress the PR-1 engine
        results["large_request_ratio"] = (
            results["coalesced"]["segments_per_sec"] /
            results["pipelined"]["segments_per_sec"])

    # ---- many-small-requests: the north-star workload (real tiny models) ----
    if "many_small" in sel:
        sizes = [1, 2, 3, 4, 6]             # all <= segment_size/2 = 32
        srng = np.random.default_rng([seed, 1])
        Xs = [srng.integers(0, 512,
                            (sizes[i % len(sizes)], seq)).astype(np.int32)
              for i in range(small_concurrency)]
        many = {}
        for name, coalesce in (("pipelined", False), ("coalesced", True)):
            with InferenceSystem(small_cfgs, small_params, alloc_small,
                                 segment_size=64, max_seq=seq,
                                 device_combine=True, coalesce=coalesce,
                                 max_in_flight=small_concurrency,
                                 max_wait_us=small_max_wait_us) as system:
                many[name] = _measure_many_small(system, Xs, small_rounds)
        many["speedup"] = (many["coalesced"]["segments_per_sec"] /
                           many["pipelined"]["segments_per_sec"])
        results["many_small"] = many

    # ---- mixed-priority: SLO traffic behind a bulk scan (real tiny models) --
    if "mixed_priority" in sel:
        srng = np.random.default_rng([seed, 2])
        bulk_X = srng.integers(0, 512, (mixed_bulk, seq)).astype(np.int32)
        small_Xs = [srng.integers(0, 512, (2 + i % 3, seq)).astype(np.int32)
                    for i in range(mixed_smalls)]
        # segment_size 16 keeps compiled chunks small and dispatch_ahead=1
        # keeps the committed (non-preemptible) window shallow: on a shared
        # device, every committed bulk chunk is queue time a high-priority
        # chunk cannot jump — the SLO deployment knob the chunk-granular
        # pipeline exposes (DESIGN.md §3)
        mixed = {}
        for mode, high in (("fifo", False), ("priority", True)):
            with InferenceSystem(small_cfgs, small_params, alloc_small,
                                 segment_size=16, max_seq=seq,
                                 device_combine=True, coalesce=True,
                                 max_in_flight=32, dispatch_ahead=1,
                                 max_wait_us=small_max_wait_us) as system:
                mixed[mode] = _measure_mixed_priority(
                    system, bulk_X, small_Xs, mixed_rounds,
                    high_priority=high)
        mixed["hp_p50_improvement"] = (mixed["fifo"]["high"]["p50_ms"] /
                                       mixed["priority"]["high"]["p50_ms"])
        mixed["hp_p99_improvement"] = (mixed["fifo"]["high"]["p99_ms"] /
                                       mixed["priority"]["high"]["p99_ms"])
        mixed["throughput_ratio"] = (mixed["priority"]["segments_per_sec"] /
                                     mixed["fifo"]["segments_per_sec"])
        results["mixed_priority"] = mixed

    # ---- skewed_load: one hot member, work stealing off vs on (ISSUE 4) -----
    if "skewed_load" in sel:
        skew_devs = host_cpus(2, memory_bytes=8 * GiB)
        skewed = {}
        for mode, steal in (("no_steal", False), ("steal", True)):
            skewed[mode] = _measure_skewed(small_cfgs, small_params,
                                           skew_devs, seq, skew_requests,
                                           skew_delay_us, steal, seed=seed)
        skewed["steal_throughput_ratio"] = (
            skewed["steal"]["segments_per_sec"] /
            skewed["no_steal"]["segments_per_sec"])
        results["skewed_load"] = skewed

    # ---- fault_recovery: kill a sibling mid-trace, lose nothing (ISSUE 6) ---
    if "fault_recovery" in sel:
        results["fault_recovery"] = _measure_fault_recovery(
            small_cfgs, small_params, seq, fault_requests, fault_delay_us,
            seed=seed)

    # ---- overload_brownout: 3x saturation, brownout off vs on (ISSUE 7) -----
    if "overload_brownout" in sel:
        overload = {}
        for mode, on in (("off", False), ("on", True)):
            overload[mode] = _measure_overload_brownout(
                small_cfgs, small_params, seq, overload_requests,
                overload_pace_s, overload_cheap_us, overload_heavy_us,
                brownout=on, seed=seed)
        overload["completed_or_shed_ratio"] = \
            overload["on"]["completed_or_shed_ratio"]
        overload["brownout_p99_improvement"] = (
            overload["off"]["p99_ms"] / max(overload["on"]["p99_ms"], 1e-9))
        results["overload_brownout"] = overload

    # ---- quantized_members: int8 speedup + fused-combine parity (ISSUE 10) --
    if "quantized_members" in sel:
        results["quantized_members"] = _measure_quantized_members(
            small_cfgs, small_params, seq, quant_requests, quant_delay_us,
            seed=seed)

    # ---- tracing_overhead: span layer on vs off, <= 5% budget (ISSUE 9) -----
    if "tracing_overhead" in sel:
        results["tracing_overhead"] = _measure_tracing_overhead(
            cfgs, params, alloc, X, seq, requests)

    # ---- sim_fidelity: record a real run, replay in-sim (DESIGN.md §12) -----
    if "sim_fidelity" in sel:
        results["sim_fidelity"] = _measure_sim_fidelity(
            small_cfgs, small_params, seq, fidelity_requests,
            fidelity_pace_s, fidelity_cheap_us, fidelity_heavy_us,
            seed=seed)

    if csv:
        print(f"serving_hotpath:rng_seed,{seed},")
        if "core" in sel:
            print("serving_hotpath:variant,segments_per_sec,"
                  "messages_per_request")
            for name in ("seed", "pipelined", "coalesced"):
                r = results[name]
                print(f"serving_hotpath:{name},{r['segments_per_sec']:.1f},"
                      f"{r['messages_per_request']:.1f}")
            print(f"serving_hotpath:speedup,{results['speedup']:.2f},")
            print(f"serving_hotpath:large_request_ratio,"
                  f"{results['large_request_ratio']:.3f},")
        if "many_small" in sel:
            many = results["many_small"]
            for name in ("pipelined", "coalesced"):
                r = many[name]
                print(f"serving_hotpath:many_small.{name},"
                      f"{r['segments_per_sec']:.1f},"
                      f"{r['messages_per_request']:.1f}")
                print(f"serving_hotpath:many_small.{name}"
                      f".padding_efficiency,{r['padding_efficiency']:.3f},")
            print(f"serving_hotpath:many_small.speedup,"
                  f"{many['speedup']:.2f},")
        if "mixed_priority" in sel:
            mixed = results["mixed_priority"]
            for mode in ("fifo", "priority"):
                r = mixed[mode]
                print(f"serving_hotpath:mixed_priority.{mode}"
                      f".high_p50/p99_ms,"
                      f"{r['high']['p50_ms']:.1f},{r['high']['p99_ms']:.1f}")
                print(f"serving_hotpath:mixed_priority.{mode}"
                      f".bulk_p50/p99_ms,"
                      f"{r['bulk']['p50_ms']:.1f},{r['bulk']['p99_ms']:.1f}")
                print(f"serving_hotpath:mixed_priority.{mode}"
                      f".segments_per_sec,{r['segments_per_sec']:.1f},")
            print(f"serving_hotpath:mixed_priority.hp_p50_improvement,"
                  f"{mixed['hp_p50_improvement']:.2f},")
            print(f"serving_hotpath:mixed_priority.hp_p99_improvement,"
                  f"{mixed['hp_p99_improvement']:.2f},")
            print(f"serving_hotpath:mixed_priority.throughput_ratio,"
                  f"{mixed['throughput_ratio']:.3f},")
        if "skewed_load" in sel:
            skewed = results["skewed_load"]
            for mode in ("no_steal", "steal"):
                r = skewed[mode]
                print(f"serving_hotpath:skewed_load.{mode},"
                      f"{r['segments_per_sec']:.1f},"
                      f"{r['stolen_descriptors']}")
            print(f"serving_hotpath:skewed_load.steal_throughput_ratio,"
                  f"{skewed['steal_throughput_ratio']:.2f},")
        if "fault_recovery" in sel:
            fr = results["fault_recovery"]
            print(f"serving_hotpath:fault_recovery.completed_ratio,"
                  f"{fr['completed_ratio']:.3f},{fr['segments_replayed']}")
            print(f"serving_hotpath:fault_recovery.recovery_s,"
                  f"{fr['recovery_s']:.4f},{fr['recovery_ok']:.0f}")
        if "overload_brownout" in sel:
            overload = results["overload_brownout"]
            for mode in ("off", "on"):
                r = overload[mode]
                print(f"serving_hotpath:overload_brownout.{mode}"
                      f".p50/p99_ms,{r['p50_ms']:.1f},{r['p99_ms']:.1f}")
                print(f"serving_hotpath:overload_brownout.{mode}"
                      f".completed/shed,{r['completed']},{r['shed']}")
            print(f"serving_hotpath:overload_brownout"
                  f".completed_or_shed_ratio,"
                  f"{overload['completed_or_shed_ratio']:.3f},")
            print(f"serving_hotpath:overload_brownout"
                  f".brownout_p99_improvement,"
                  f"{overload['brownout_p99_improvement']:.2f},")
        if "quantized_members" in sel:
            qm = results["quantized_members"]
            for mode in ("fp32", "int8"):
                r = qm[mode]
                print(f"serving_hotpath:quantized_members.{mode},"
                      f"{r['segments_per_sec']:.1f},"
                      f"{r['fake_delay_us']}")
            print(f"serving_hotpath:quantized_members.quant_speedup,"
                  f"{qm['quant_speedup']:.2f},")
            print(f"serving_hotpath:quantized_members.parity_rel_err,"
                  f"{qm['parity_rel_err']:.4f},{qm['subset_rel_err']:.4f}")
            print(f"serving_hotpath:quantized_members.quant_parity_ok,"
                  f"{qm['quant_parity_ok']:.0f},{qm['h2d_staged']}")
        if "tracing_overhead" in sel:
            to = results["tracing_overhead"]
            print(f"serving_hotpath:tracing_overhead.off/on_segs_per_sec,"
                  f"{to['off_segments_per_sec']:.1f},"
                  f"{to['on_segments_per_sec']:.1f}")
            print(f"serving_hotpath:tracing_overhead.overhead_ratio,"
                  f"{to['overhead_ratio']:.3f},{to['trace_events']}")
            print(f"serving_hotpath:tracing_overhead.overhead_ok,"
                  f"{to['overhead_ok']:.0f},")
        if "sim_fidelity" in sel:
            sf = results["sim_fidelity"]
            print(f"serving_hotpath:sim_fidelity.real.req_per_s/p99_ms,"
                  f"{sf['real']['req_per_s']:.1f},{sf['real']['p99_ms']:.1f}")
            print(f"serving_hotpath:sim_fidelity.sim.req_per_s/p99_ms,"
                  f"{sf['sim']['req_per_s']:.1f},{sf['sim']['p99_ms']:.1f}")
            print(f"serving_hotpath:sim_fidelity.throughput_ratio,"
                  f"{sf['throughput_ratio']:.3f},")
            print(f"serving_hotpath:sim_fidelity.p99_ratio,"
                  f"{sf['p99_ratio']:.3f},")
            print(f"serving_hotpath:sim_fidelity.fidelity_ok,"
                  f"{sf['fidelity_ok']:.0f},")
        if "core" in sel:
            for name in ("pipelined", "coalesced"):
                for stage, t in results[name]["stage_timings"].items():
                    print(f"serving_hotpath:{name}.{stage},"
                          f"{t['total_s']:.4f},{t['count']}")
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="serving hot-path A/B benchmark")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for every scenario's inputs and "
                         "member skews (recorded in the results as "
                         "rng_seed)")
    ap.add_argument("--scenario", action="append", default=[],
                    metavar="NAME", choices=SCENARIOS,
                    help=f"run only the named scenarios (repeatable); "
                         f"default all of {list(SCENARIOS)}")
    ap.add_argument("--replay-trace", default=None, metavar="PATH",
                    help="replay a recorded request trace "
                         "(launch/serve.py --record-trace or "
                         "system.trace_recorder) against a fake-device "
                         "system instead of running scenarios")
    ap.add_argument("--replay-speed", type=float, default=1.0,
                    help="time-compression factor for --replay-trace")
    args = ap.parse_args(argv)
    if args.replay_trace:
        replay_trace(args.replay_trace, speed=args.replay_speed)
    else:
        run(seed=args.seed, scenarios=args.scenario or None)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
