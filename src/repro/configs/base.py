"""Composable model configuration covering all assigned architecture families.

One ``ModelConfig`` describes any of the six families (dense / moe / ssm /
hybrid / vlm / audio).  Per-layer heterogeneity (sliding-window patterns,
cross-attention layers, hybrid blocks) is expressed through a repeating
*pattern unit*: the layer stack is ``num_layers == repeats * len(pattern)``
copies of the unit, which lets the model assembly ``lax.scan`` over repeats
with the unit unrolled inside (compile size independent of depth).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds appearing in pattern units.
ATTN = "attn"          # global self-attention
SWA = "swa"            # sliding-window self-attention
CROSS = "cross"        # cross-attention to frontend embeddings (VLM)
SSM = "ssm"            # Mamba2 SSD mixer
HYBRID = "hybrid"      # parallel attention + SSD heads (Hymba)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False       # llama4-style always-on shared expert
    d_ff_shared: int = 0
    router_aux_coef: float = 0.01     # load-balance loss coefficient
    capacity_factor: float = 1.25     # used by the "capacity" (GShard) impl
    impl: str = "capacity"            # "capacity" (TPU expert-parallel, may drop
                                      # tokens) | "dense" (dropless, exact; used by
                                      # reduced configs and correctness tests)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2                   # d_inner = expand * d_model
    d_conv: int = 4
    chunk: int = 64                   # SSD chunk length
    # number of heads derived: expand * d_model // head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    pattern: Tuple[str, ...] = (ATTN,)
    sliding_window: int = 4096        # window for SWA layers
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # VLM / audio frontend stub: number of frontend tokens cross-attended to.
    frontend_tokens: int = 0
    frontend_dim: int = 0             # 0 -> d_model
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma-style sqrt(d_model) embed scaling
    vocab_pad_to: int = 256           # pad vocab so the sharded dim divides the mesh
    source: str = ""                  # citation for the config
    # families with no MLP block (pure mamba2): d_ff == 0

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not a multiple of "
                f"pattern unit {len(self.pattern)}")

    # ---- derived quantities -------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def ssm_heads(self) -> int:
        if self.ssm is None:
            return 0
        return (self.ssm.expand * self.d_model) // self.ssm.head_dim

    @property
    def d_inner(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.expand * self.d_model

    @property
    def fdim(self) -> int:
        return self.frontend_dim or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every layer, unrolled."""
        return tuple(self.pattern[i % len(self.pattern)] for i in range(self.num_layers))

    @property
    def has_attention(self) -> bool:
        return any(k in (ATTN, SWA, CROSS, HYBRID) for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer needs an unbounded full-attention KV cache."""
        return all(k in (SSM, SWA) or (k == HYBRID and self.sliding_window > 0)
                   for k in self.pattern)

    # ---- analytic size model (used by core.memory and the roofline) ---------
    def param_count(self) -> int:
        """Exact parameter count of the unpadded model (embedding included)."""
        total = self.vocab_size * self.d_model           # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model      # lm head
        for kind in self.layer_kinds():
            total += self._layer_params(kind)
        total += self.d_model                            # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = dataclasses.replace(self, moe=None, d_ff=0)
        total = dense_like.param_count()
        per_expert = 3 * self.d_model * m.d_ff_expert
        total += self.num_layers * (
            m.top_k * per_expert
            + self.d_model * m.num_experts                 # router
            + (3 * self.d_model * m.d_ff_shared if m.shared_expert else 0))
        return total

    def _layer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.hd
        n = 0
        if kind in (ATTN, SWA, CROSS, HYBRID):
            n += d * self.num_heads * hd                  # q
            kv_src = self.fdim if kind == CROSS else d
            n += 2 * kv_src * self.num_kv_heads * hd      # k, v
            n += self.num_heads * hd * d                  # o
            if self.qk_norm:
                n += 2 * hd
            n += d                                        # pre-norm
        if kind in (SSM, HYBRID):
            di, s = self.d_inner, self.ssm
            n += d * (2 * di + 2 * s.d_state + self.ssm_heads)   # in_proj (x,z,B,C,dt)
            n += s.d_conv * (di + 2 * s.d_state)                 # conv
            n += 3 * self.ssm_heads                              # A_log, D, dt_bias
            n += di                                              # gated norm
            n += di * d                                          # out_proj
            n += d if kind == SSM else 0                         # pre-norm (hybrid shares attn norm)
        # MLP / MoE after the mixer
        if kind != SSM or self.d_ff > 0:
            if self.moe is not None:
                m = self.moe
                n += self.d_model * m.num_experts                      # router
                n += m.num_experts * 3 * self.d_model * m.d_ff_expert  # experts
                if m.shared_expert:
                    n += 3 * self.d_model * m.d_ff_shared
                n += self.d_model                                      # pre-norm
            elif self.d_ff > 0:
                n += 3 * self.d_model * self.d_ff                      # swiglu
                n += self.d_model                                      # pre-norm
        return n

    def kv_cache_bytes(self, batch: int, seq: int, dtype_bytes: int = 2) -> int:
        """KV + SSM state bytes for a decode cache of length ``seq``."""
        total = 0
        for kind in self.layer_kinds():
            if kind in (ATTN, CROSS):
                length = self.frontend_tokens if kind == CROSS else seq
                total += 2 * batch * length * self.num_kv_heads * self.hd * dtype_bytes
            elif kind == SWA:
                total += 2 * batch * min(seq, self.sliding_window) * \
                    self.num_kv_heads * self.hd * dtype_bytes
            elif kind == HYBRID:
                win = min(seq, self.sliding_window) if self.sliding_window else seq
                total += 2 * batch * win * self.num_kv_heads * self.hd * dtype_bytes
            if kind in (SSM, HYBRID):
                s = self.ssm
                total += batch * self.ssm_heads * s.head_dim * s.d_state * 4
                total += batch * (self.d_inner + 2 * s.d_state) * (s.d_conv - 1) * dtype_bytes
        return total

    def flops_per_token(self, seq: int = 1) -> float:
        """~2*N_active forward (x3 for train); attention/SSM mixer terms added."""
        n = self.active_param_count()
        mixer = 0
        win = min(seq, self.sliding_window) if self.sliding_window else seq
        for kind in self.layer_kinds():
            if kind == ATTN:
                mixer += 2 * 2 * seq * self.num_heads * self.hd
            elif kind == CROSS:
                mixer += 2 * 2 * self.frontend_tokens * self.num_heads * self.hd
            elif kind in (SWA, HYBRID):
                mixer += 2 * 2 * win * self.num_heads * self.hd
            if kind in (SSM, HYBRID) and self.ssm is not None:
                s = self.ssm
                # SSD dual form: intra-chunk (chunk-local attention over
                # d_inner) + B/C state contractions per token
                mixer += 2 * 2 * s.chunk * self.d_inner
                mixer += 2 * 2 * self.d_inner * s.d_state
        return 2 * n + mixer

    def reduced(self, layers: int = 0, d_model: int = 256, max_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests / serving benches."""
        unit = len(self.pattern)
        layers = layers or (2 * unit if unit <= 3 else unit)
        layers = max(unit, (layers // unit) * unit)
        heads = max(2, min(4, self.num_heads))
        kv = 1 if self.num_kv_heads == 1 else 2
        hd = min(64, max(32, d_model // heads))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(max_experts, self.moe.num_experts),
                top_k=min(self.moe.top_k, 2), d_ff_expert=d_model,
                d_ff_shared=d_model if self.moe.shared_expert else 0,
                impl="dense")
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=16)
        return dataclasses.replace(
            self, name=self.name + "-reduced", num_layers=layers, d_model=d_model,
            num_heads=heads, num_kv_heads=kv, head_dim=hd,
            d_ff=0 if self.d_ff == 0 else d_model * 2,
            vocab_size=vocab, sliding_window=min(self.sliding_window, 64) or 64,
            moe=moe, ssm=ssm,
            frontend_tokens=16 if self.frontend_tokens else 0,
            frontend_dim=d_model if self.frontend_dim else 0,
            vocab_pad_to=8)
