"""Paper Table III: the Best-Batch-Size baseline vs our allocation-matrix
optimizer (same asynchronous inference system underneath, different
allocations) — throughput and number of offline benchmarks."""
from __future__ import annotations

import numpy as np

from repro.configs import ensemble
from repro.core import (AllocationOptimizer, AnalyticBench, host_cpus,
                        simulated_gpus)
from repro.core.bbs import analytic_single_bench, best_batch_strategy

GiB = 1024 ** 3


def run(csv=True, seq: int = 128):
    rows = []
    cases = [("ENS1", 1), ("ENS4", 4), ("ENS12", 12)]
    for name, n_gpu in cases:
        cfgs = ensemble(name)
        devices = simulated_gpus(n_gpu, memory_bytes=int(0.15 * GiB)) + \
            host_cpus(1, memory_bytes=1 * GiB)
        bench = AnalyticBench(cfgs, seq=seq)
        bbs_alloc, nb = best_batch_strategy(cfgs, devices,
                                            analytic_single_bench(seq=seq))
        bbs_score = bench(bbs_alloc)
        opt = AllocationOptimizer(cfgs, devices, bench, max_iter=10,
                                  max_neighs=100, seq=seq)
        res = opt.optimize()
        rows.append((name, n_gpu, round(bbs_score, 1), nb,
                     round(res.final_score, 1), res.trace.evaluated,
                     round(res.final_score / max(bbs_score, 1e-9), 2)))
    if csv:
        print("table3:ensemble,gpus,bbs_imgs,bbs_nbench,ours_imgs,ours_nbench,speedup")
        for r in rows:
            print("table3:" + ",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run()
