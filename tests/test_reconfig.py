"""Online-reconfiguration tests (ISSUE 4): live instance spawn/drain with
zero dropped or corrupted in-flight requests (exact-result assertions),
cross-worker work stealing with expected-map migration (bit-identical
combine results vs no-steal, member subsets, device_combine parity),
re-entrant quiesce, deadline-aware linger, the LiveBench profile, and the
controller's replan/apply loop."""
import queue
import threading
import time

import numpy as np
import jax
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, AnalyticBench, host_cpus
from repro.serving.admission import AdmissionQueue
from repro.serving.combiner import DeviceCombiner
from repro.serving.control import LiveBench, ReconfigController
from repro.serving.control.stealing import balance_member, steal_from
from repro.serving.segments import (FLUSH, PRIORITY_HIGH, DeadlineExceeded,
                                    PredictOptions, Request)
from repro.serving.system import InferenceSystem
from repro.serving.worker import Worker

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


# ---- AdmissionQueue.steal ----------------------------------------------------

def test_admission_queue_steal_order_sentinels_and_priority():
    q = AdmissionQueue()
    items = [(f"r{i}", 0) for i in range(6)]
    for it in items:
        q.put(it)
    assert q.steal(2) == items[4:]        # newest first, order preserved
    assert q.qsize() == 4
    assert [q.get_nowait() for _ in range(4)] == items[:4]   # head untouched
    q.put(("a", 0))
    q.put(FLUSH)                          # draining marker at the tail
    q.put(("b", 0))
    assert q.steal(10) == [("b", 0)]      # stops at the sentinel
    q2 = AdmissionQueue()
    q2.put(("hi", 0), PRIORITY_HIGH)
    assert q2.steal(10) == []             # high-priority work is never stolen


def test_admission_queue_drain_descriptors_moves_both_classes():
    """Drain-side migration pops BOTH priority classes (high first, FIFO
    within each) and leaves sentinels in place for the retiring batcher."""
    q = AdmissionQueue()
    q.put(("n0", 0))
    q.put(FLUSH)
    q.put(("n1", 0))
    q.put(("h0", 0), PRIORITY_HIGH)
    q.put(("h1", 0), PRIORITY_HIGH)
    assert q.drain_descriptors() == [("h0", 0), ("h1", 0),
                                     ("n0", 0), ("n1", 0)]
    assert q.qsize() == 1                 # the FLUSH sentinel stays
    assert q.get_nowait() == FLUSH


# ---- combiner expected-map migration -----------------------------------------

def _mk_request(n, num_classes=8, segment_size=16, members=(0, 1),
                weights=(0.6, 0.4)):
    return Request(0, np.zeros((n, SEQ), np.int32), n, num_classes,
                   segment_size, list(members),
                   {m: w for m, w in zip(members, weights)}, "weighted")


def test_combiner_unexpect_flushes_early_and_dest_closes():
    """Stealing member 0's descriptor off device A after member 1's rows
    already folded must flush A's partial immediately (count=1); the
    destination combiner then closes with member 0's rows alone.  The two
    partials sum to exactly the no-steal combine."""
    req = _mk_request(12)
    rng = np.random.default_rng(0)
    P0 = rng.normal(size=(12, 8)).astype(np.float32)
    P1 = rng.normal(size=(12, 8)).astype(np.float32)
    qa, qb = queue.Queue(), queue.Queue()
    a, b = DeviceCombiner("dA", qa), DeviceCombiner("dB", qb)
    a.begin(req, {0: 2})                  # both members expected on dA
    a.add(req, 0, 1, P1)                  # member 1 lands before the steal
    assert qa.empty()
    assert a.unexpect(req, 0)             # member 0's descriptor stolen away
    msg_a = qa.get_nowait()               # dA closed early with count=1
    assert msg_a.count == 1 and msg_a.m is None
    np.testing.assert_array_equal(msg_a.P, 0.4 * P1)
    assert not a._parts and not a._expected
    b.expect_one(req, 0)                  # destination side of the steal
    b.add(req, 0, 0, P0)
    msg_b = qb.get_nowait()
    assert msg_b.count == 1
    np.testing.assert_array_equal(msg_b.P, 0.6 * P0)
    np.testing.assert_allclose(msg_a.P + msg_b.P, 0.6 * P0 + 0.4 * P1,
                               atol=1e-6)


def test_combiner_unexpect_before_any_fold_moves_whole_expectation():
    req = _mk_request(10)
    qa = queue.Queue()
    a = DeviceCombiner("dA", qa)
    a.begin(req, {0: 2})
    assert a.unexpect(req, 0)
    assert qa.empty()                     # nothing folded yet: no flush
    assert a._expected[req.rid][0] == (1, 10)
    assert a.unexpect(req, 0)             # last member leaves the device
    assert not a._expected and qa.empty()
    assert not a.unexpect(req, 0)         # now untracked: refuse


# ---- end-to-end steal: bit-identical results, maps migrate -------------------

def _stall_batcher(monkeypatch, worker_ids):
    """Freeze the named workers' batchers until the returned event is set —
    descriptors routed to them sit in their admission queues, giving the
    steal tests a deterministic backlog."""
    release = threading.Event()
    orig = Worker._batcher

    def stalling(self):
        if self.worker_id in worker_ids:
            release.wait(60.0)
        return orig(self)

    monkeypatch.setattr(Worker, "_batcher", stalling)
    return release


@pytest.mark.parametrize("device_combine", [True, False])
def test_steal_end_to_end_bit_identical(ens2, monkeypatch, device_combine):
    """Stolen descriptors produce bit-identical results vs no-steal,
    including member subsets, with and without the device combine.  The
    victim's batcher is frozen, so every one of its descriptors completes
    via the sibling — proving the re-route AND the expected-map migration
    (the request could never finish otherwise)."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [8, 0]])                # member 0 data-parallel on d0+d1
    member_sets = [[0], [0, 1], [0], [0, 1]]
    rng = np.random.default_rng(5)
    Xs = [rng.integers(0, 512, (24, SEQ)).astype(np.int32)
          for _ in member_sets]

    with make_system(cfgs, params, A, segment_size=32,
                     device_combine=device_combine, max_in_flight=8) as ref:
        Y_ref = [ref.predict(x, members=m, timeout=120.0)
                 for x, m in zip(Xs, member_sets)]

    release = _stall_batcher(monkeypatch, {"w0.0"})
    with make_system(cfgs, params, A, segment_size=32,
                     device_combine=device_combine, max_in_flight=8) as s:
        try:
            w_stalled = [w for w in s.instances(0)
                         if w.worker_id == "w0.0"][0]
            w_sibling = [w for w in s.instances(0) if w is not w_stalled][0]
            handles = [s.predict_async(x, members=m)
                       for x, m in zip(Xs, member_sets)]
            assert w_stalled.input_queue.qsize() > 0
            # re-route EVERYTHING queued on the frozen instance
            moved = steal_from(s, w_stalled, w_sibling, max_items=100)
            assert moved > 0
            # all requests complete although w0.0 never ran its batcher
            Ys = [h.result(120.0) for h in handles]
        finally:
            release.set()
    for y, y_ref in zip(Ys, Y_ref):
        np.testing.assert_array_equal(y, y_ref)


def test_balance_member_uses_drain_time_not_depth(ens2, monkeypatch):
    """With a live profile, the balancer weighs backlog by measured service
    time: a fast sibling with the deeper queue must NOT be stolen from."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [8, 0]])
    release = _stall_batcher(monkeypatch, {"w0.0", "w1.0"})
    with make_system(cfgs, params, A, segment_size=32, fake=True,
                     max_in_flight=16) as s:
        try:
            lb = LiveBench(cfgs, seq=SEQ)
            w0 = [w for w in s.instances(0) if w.worker_id == "w0.0"][0]
            w1 = [w for w in s.instances(0) if w.worker_id == "w1.0"][0]
            # profile says w0's device serves segments 10x faster
            lb.observe(0, w0.device.key(), 8, 8, 0.001)
            lb.observe(0, w1.device.key(), 8, 8, 0.010)
            for _ in range(6):            # stripe 3 descriptors to each
                s.predict_async(np.zeros((32, SEQ), np.int32), members=[0])
            d0, d1 = w0.input_queue.qsize(), w1.input_queue.qsize()
            assert d0 == d1 == 3          # equal depth...
            moved = balance_member(s, 0, threshold=1, max_items=100,
                                   profile=lb)
            # ...but very different drain times: work moves to the fast w0
            assert moved > 0
            assert w0.input_queue.qsize() > w1.input_queue.qsize()
        finally:
            release.set()


# ---- live rebalance: spawn + drain under load --------------------------------

def test_live_rebalance_spawn_drain_exact_results(ens2):
    """A live rebalance (instance add + drain) mid-stream completes with
    zero dropped or corrupted in-flight requests: every prediction is
    bit-identical to a static system's (the ISSUE 4 acceptance)."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [0, 0]])                # d1 idle at deploy time
    rng = np.random.default_rng(6)
    Xs = [rng.integers(0, 512, (40, SEQ)).astype(np.int32)
          for _ in range(12)]

    with make_system(cfgs, params, A, segment_size=32,
                     max_in_flight=4) as ref:
        Y_ref = [ref.predict(x, timeout=120.0) for x in Xs]

    with make_system(cfgs, params, A, segment_size=32, max_in_flight=4) as s:
        handles = [s.predict_async(x) for x in Xs[:4]]
        w_new = s.spawn_instance(1, 0, 8)         # same compiled batch
        assert s.alloc.A[1, 0] == 8
        handles += [s.predict_async(x) for x in Xs[4:8]]
        old = [w for w in s.instances(0) if w is not w_new][0]
        s.drain_instance(old, wait=True)          # migrate + retire
        assert s.alloc.A[0, 0] == 0
        assert s.instances(0) == [w_new]
        handles += [s.predict_async(x) for x in Xs[8:]]
        Ys = [h.result(120.0) for h in handles]
    for y, y_ref in zip(Ys, Y_ref):
        np.testing.assert_array_equal(y, y_ref)


def test_drain_with_queued_backlog_migrates(ens2, monkeypatch):
    """Draining an instance whose queue is deep re-routes the backlog to
    siblings instead of waiting it out — and nothing is lost."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [8, 0]])
    release = _stall_batcher(monkeypatch, {"w0.0"})
    with make_system(cfgs, params, A, segment_size=32,
                     max_in_flight=8) as s:
        try:
            rng = np.random.default_rng(7)
            Xs = [rng.integers(0, 512, (24, SEQ)).astype(np.int32)
                  for _ in range(6)]
            w_stalled = [w for w in s.instances(0)
                         if w.worker_id == "w0.0"][0]
            opts = PredictOptions(priority="high")
            handles = [s.predict_async(x, members=[0]) for x in Xs]
            # high-priority work queued on the victim must migrate too
            handles.append(s.predict_async(Xs[0], members=[0], options=opts))
            handles.append(s.predict_async(Xs[1], members=[0], options=opts))
            assert w_stalled.input_queue.qsize() > 0
            # drain the frozen worker: its queue must migrate, not block
            s.drain_instance(w_stalled, wait=False)
            Ys = [h.result(120.0) for h in handles]
            assert all(y.shape == (24, cfgs[0].vocab_size) for y in Ys)
        finally:
            release.set()


def test_spawn_racing_shutdown_never_registers(ens2, monkeypatch):
    """A spawn whose warm-up overlaps shutdown() must not splice a live
    worker into the dead system (leaked threads, post-shutdown mutation)."""
    cfgs, params = ens2
    s = make_system(cfgs, params, np.array([[8, 8], [0, 0]]),
                    segment_size=32, fake=True)
    orig = InferenceSystem._make_worker

    def slow_make(self, *a, **kw):
        w = orig(self, *a, **kw)
        threading.Thread(target=s.shutdown).start()   # race the registration
        time.sleep(0.3)
        return w

    monkeypatch.setattr(InferenceSystem, "_make_worker", slow_make)
    with pytest.raises(RuntimeError, match="shut down"):
        s.spawn_instance(1, 0, 8)
    assert all(w.device_idx == 0 for w in s.workers)
    assert s.alloc.A[1, 0] == 0


def test_submit_racing_shutdown_fails_fast(ens2):
    """predict_async blocked on the in-flight window when shutdown() lands
    must raise instead of enqueuing descriptors behind SHUTDOWN (where the
    batcher would discard them and the handle would hang)."""
    cfgs, params = ens2
    s = make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                    fake=True, fake_delay_us=50_000, max_in_flight=1)
    h = s.predict_async(np.zeros((32, SEQ), np.int32))   # fills the window
    errs = []

    def submit():
        try:
            s.predict_async(np.zeros((32, SEQ), np.int32))
        except RuntimeError as e:
            errs.append(e)
    t = threading.Thread(target=submit)
    t.start()
    time.sleep(0.05)                      # submitter is blocked on the window
    s.shutdown()
    t.join(30.0)
    assert not t.is_alive() and len(errs) == 1


def test_drain_sole_instance_refused(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                     fake=True) as s:
        with pytest.raises(ValueError, match="sole instance"):
            s.drain_instance(s.instances(0)[0])


def test_zero_work_requests_resolve_without_the_pipeline(ens2):
    """Regression: an empty member list or 0-row input must resolve
    immediately instead of completing synchronously inside _submit — the
    completion callback takes the topology lock the submitter holds
    (self-deadlock caught in review)."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True) as s:
        y = s.predict_async(np.zeros((5, SEQ), np.int32),
                            members=[]).result(10.0)
        assert y.shape == (5, cfgs[0].vocab_size) and np.all(y == 0)
        y = s.predict_async(np.zeros((0, SEQ), np.int32)).result(10.0)
        assert y.shape == (0, cfgs[0].vocab_size)
        # the system is still alive afterwards
        assert s.predict(np.zeros((3, SEQ), np.int32),
                         timeout=30.0).shape == (3, cfgs[0].vocab_size)


# ---- re-entrant quiesce ------------------------------------------------------

def test_quiesce_then_predict_cycles(ens2):
    """Regression (ISSUE 4 satellite): quiesce() -> predict_async() ->
    quiesce() cycles are legal — quiesce is a flush, not a teardown — and
    quiesce(wait=True) blocks until every batcher processed its flush."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=30_000_000) as s:
        for _ in range(3):
            h = s.predict_async(np.zeros((3, SEQ), np.int32))
            time.sleep(0.05)
            assert not h.done.is_set()    # lingering in an open slot
            assert s.quiesce(wait=True, timeout=30.0)
            assert np.all(h.result(30.0) == 0)
        # quiesce stays legal across a topology change
        s.spawn_instance(0, 0, 8)
        h = s.predict_async(np.zeros((5, SEQ), np.int32))
        assert s.quiesce(wait=True, timeout=30.0)
        assert h.result(30.0).shape == (5, cfgs[0].vocab_size)


# ---- deadline-aware linger ---------------------------------------------------

def test_deadline_bounds_linger(ens2):
    """A tight-deadline row never waits out a full linger: the open slot's
    deadline is clamped to half the row's remaining deadline budget
    (ROADMAP item f)."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=30_000_000) as s:
        t0 = time.perf_counter()
        Y = s.predict(np.zeros((3, SEQ), np.int32), timeout=30.0,
                      options=PredictOptions(deadline_ms=4000))
        elapsed = time.perf_counter() - t0
        assert elapsed < 3.5              # flushed at ~2s, not the 30s linger
        assert np.all(Y == 0)


def test_deadline_linger_expired_request_still_fails_fast(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=30_000_000) as s:
        h = s.predict_async(np.zeros((3, SEQ), np.int32),
                            options=PredictOptions(deadline_ms=0.01))
        with pytest.raises(DeadlineExceeded):
            h.result(30.0)


# ---- LiveBench ---------------------------------------------------------------

def test_livebench_profile_fallback_and_demand():
    cfgs = ensemble("ENS4")[:2]
    devs = host_cpus(2, memory_bytes=8 * 1024 ** 3)
    lb = LiveBench(cfgs, seq=SEQ, alpha=0.5)
    key = devs[0].key()
    lb.observe(0, key, 8, 8, 0.010)
    lb.observe(0, key, 8, 8, 0.020)       # EWMA moves toward the new sample
    assert lb.worker_time(devs[0], 0, 8) == pytest.approx(0.015)
    # nearest-bucket scaling with an overhead floor
    assert lb.worker_time(devs[0], 0, 32) == pytest.approx(0.015 * 4)
    assert lb.worker_time(devs[0], 0, 1) == pytest.approx(0.015 * 0.25)
    # unseen (member, device): roofline fallback
    analytic = AnalyticBench(cfgs, seq=SEQ)
    assert lb.worker_time(devs[1], 1, 8) == \
        pytest.approx(analytic.worker_time(devs[1], cfgs[1], 8))
    # segment_time: None when cold, chunks x per-chunk when warm
    assert lb.segment_time(1, devs[1].key(), 8, 32) is None
    assert lb.segment_time(0, key, 8, 32) == pytest.approx(0.015 * 4)
    # demand shares drift with traffic
    for _ in range(50):
        lb.note_request([0], 32)
    shares = lb.demand_shares()
    assert shares[0] > 0.9 and shares[0] + shares[1] == pytest.approx(1.0)


def test_livebench_bench_prefers_capacity_for_the_hot_member():
    cfgs = ensemble("ENS4")[:2]
    devs = host_cpus(2, memory_bytes=8 * 1024 ** 3)
    names = [c.name for c in cfgs]
    lb = LiveBench(cfgs, seq=SEQ)
    for d in devs:                        # uniform measured latencies
        for m in (0, 1):
            lb.observe(m, d.key(), 8, 8, 0.010)
    for _ in range(50):                   # member 0 runs 4x hot
        lb.note_request([0], 32)
        lb.note_request([0], 32)
        lb.note_request([0], 32)
        lb.note_request([0, 1], 32)
    extra_m0 = AllocationMatrix(devs, names, np.array([[8, 8], [8, 0]]))
    extra_m1 = AllocationMatrix(devs, names, np.array([[8, 8], [0, 8]]))
    assert lb(extra_m0) > lb(extra_m1)    # capacity should follow demand
    assert lb(AllocationMatrix(devs, names, np.zeros((2, 2), int))) == 0.0


# ---- the controller ----------------------------------------------------------

def test_controller_replans_under_demand_skew(ens2):
    """The replan loop: a hot member under 4:1 skew makes the bounded
    greedy (scored by the live bench) claim the idle device; the delta
    applies live and requests keep completing correctly."""
    cfgs, params = ens2
    A = np.array([[8, 8],
                  [0, 0]])                # d1 idle at deploy time
    X = np.random.default_rng(8).integers(0, 512, (64, SEQ)).astype(np.int32)
    with make_system(cfgs, params, A, segment_size=32,
                     max_in_flight=8) as s:
        Y_ref = s.predict(X, timeout=120.0)
        ctl = ReconfigController(s, replan=True, steal=True,
                                 batch_sizes=(8, 16), max_iter=2,
                                 max_neighs=16, min_observations=8)
        assert s.controller is ctl
        assert not ctl.replan_once()      # profile too cold to act
        for i in range(8):                # 4:1 member skew
            s.predict(X, members=[0] if i % 5 else [0, 1], timeout=120.0)
        assert ctl.replan_once()
        assert s.generation == 1
        assert ctl.counters["applied"] == 1 and ctl.counters["spawns"] >= 1
        assert int(s.alloc.A[1].sum()) > 0         # the idle device is used
        assert s.alloc.is_valid()
        np.testing.assert_allclose(s.predict(X, timeout=120.0), Y_ref,
                                   atol=2e-5)
        stats = ctl.stats()
        assert stats["generation"] == 1
        assert stats["live"]["observations"] > 0
        assert any(e["kind"] == "applied" for e in stats["events"])


def test_controller_apply_rebatch(ens2):
    """A batch-bucket change applies as a generation-tagged replacement:
    spawn the new-batch instance, then drain the old one — the member
    stays served throughout and results stay correct."""
    cfgs, params = ens2
    A = np.array([[8, 8]])
    X = np.random.default_rng(9).integers(0, 512, (40, SEQ)).astype(np.int32)
    with make_system(cfgs, params, A, segment_size=32,
                     max_in_flight=4) as s:
        Y_ref = s.predict(X, timeout=120.0)
        ctl = ReconfigController(s, replan=False, steal=False)
        devs = s.alloc.devices
        target = AllocationMatrix(devs, s.alloc.model_names,
                                  np.array([[16, 8]]))
        ctl.apply(target)
        assert s.alloc.A.tolist() == [[16, 8]]
        (w,) = s.instances(0)
        assert w.batch_size == 16 and w.generation == 1
        assert ctl.counters["rebatches"] == 1
        np.testing.assert_allclose(s.predict(X, timeout=120.0), Y_ref,
                                   atol=2e-5)


def test_controller_steal_loop_with_simulated_devices(ens2):
    """The fast path end-to-end on simulated device time: a slow batch-8
    instance backlogs while its batch-128 sibling idles; the controller's
    balancer moves the backlog and everything completes."""
    cfgs, params = ens2
    A = np.array([[8, 0],
                  [128, 128]])
    with make_system(cfgs, params, A, segment_size=128, fake=True,
                     fake_delay_us=3000, max_in_flight=20,
                     max_wait_us=200) as s:
        ctl = ReconfigController(s, replan=False, steal=True,
                                 steal_interval_s=0.001, steal_threshold=1,
                                 steal_max=64).start()
        for _ in range(2):                # warm the live profile
            s.predict(np.zeros((128, SEQ), np.int32), members=[0])
        handles = [s.predict_async(np.zeros((128, SEQ), np.int32),
                                   members=[0]) for _ in range(20)]
        for h in handles:
            assert np.all(h.result(120.0) == 0)
        assert ctl.counters["stolen"] > 0
        ctl.stop()
