"""EnsembleClient: the one request API over every entry point (DESIGN.md §7).

The paper frames the system as a single ``f(X, A) -> {Y, S}`` interface, but
the implementation had grown three inconsistent doors — ``InferenceSystem``
(in-process), the HTTP server's adaptive batcher, and
``PredictionCache.predict_through``.  The facade subsumes all three:

  * **transport**: construct with ``system=`` for the in-process path or
    ``url=`` for a remote HTTP v2 server — call styles are identical;
  * **cache**: an optional :class:`PredictionCache` is consulted per the
    request's :class:`PredictOptions.cache` policy ("use" / "bypass" /
    "refresh"); only miss rows travel through the transport and the merged
    result preserves row order;
  * **call styles**: ``predict`` (sync), ``predict_async`` (a
    :class:`ClientHandle` future with ``result()`` / ``cancel()``), and
    ``predict_stream`` (per-segment callback as ensemble rows complete —
    in-process transport only).

Every per-request knob (priority class, deadline, member subset, combine
rule) rides on :class:`PredictOptions`, so SLO-aware admission applies the
same way whichever door a request came through.
"""
from __future__ import annotations

import json
import threading
import urllib.error
import urllib.request
from dataclasses import replace
from typing import Callable, List, Optional

import numpy as np

from repro.serving.request_cache import PredictionCache
from repro.serving.segments import (DeadlineExceeded, MemberUnavailable,
                                    Overloaded, PredictOptions,
                                    RequestCancelled, priority_level,
                                    PRIORITY_HIGH)


def quality_salt(salt: bytes, quality: float) -> bytes:
    """Cache salt for a degraded/brownout result (DESIGN.md §11): a
    quality < 1.0 prediction must never be stored under — or served for —
    a full-quality key, so the served tier partitions the key space."""
    if quality >= 1.0:
        return salt
    return salt + f"|q={quality:.6f}".encode()


class ClientHandle:
    """Facade-level future: merges cached rows with the transport's miss
    rows at ``result()`` time; ``cancel()`` propagates to the underlying
    request (in-process: through spans/combiner/accumulator accounting)."""

    def __init__(self, *, inner=None, Y: Optional[np.ndarray] = None,
                 error: Optional[BaseException] = None,
                 cached: Optional[List[Optional[np.ndarray]]] = None,
                 miss_idx: Optional[List[int]] = None,
                 X_miss: Optional[np.ndarray] = None,
                 cache: Optional[PredictionCache] = None,
                 cache_salt: bytes = b""):
        self._inner = inner            # RequestHandle / _HttpFuture, or None
        self._Y = Y                    # immediate result (every row cached)
        self._error = error
        self._cached = cached
        self._miss_idx = miss_idx
        self._X_miss = X_miss
        self._cache = cache            # insert target for resolved misses
        self._cache_salt = cache_salt

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if self._error is not None:
            raise self._error
        if self._Y is not None:
            return self._Y
        Y_miss = self._inner.result(timeout)
        if self._cache is not None:
            # quality-salted insert: a degraded partial-ensemble result
            # would otherwise poison the full-quality key and be replayed
            # at quality 1.0 long after the brownout ends
            self._cache.insert(self._X_miss, Y_miss,
                               quality_salt(self._cache_salt, self.quality()))
        if self._cached is None:       # nothing served from cache
            self._Y = Y_miss
        else:
            merged = list(self._cached)
            for j, i in enumerate(self._miss_idx):
                merged[i] = Y_miss[j]
            self._Y = np.stack(merged, axis=0)
        return self._Y

    def cancel(self) -> bool:
        if self._inner is None:
            return False
        return self._inner.cancel()

    def done(self) -> bool:
        if self._Y is not None or self._error is not None:
            return True
        return self._inner.done.is_set()

    def quality(self) -> float:
        """Fraction of the ensemble actually served (DESIGN.md §§10-11):
        1.0 = full ensemble; < 1.0 means a degraded partial combine (a
        member lost its last instance mid-request) or a brownout tier.
        Rows served from the cache under the base salt were full-quality
        when inserted (degraded results are quality-salted)."""
        if self._inner is None:
            return 1.0
        return getattr(self._inner, "quality", 1.0)

    def retry_after_s(self) -> Optional[float]:
        """Suggested backoff when the request was refused (429
        ``Overloaded``) or capacity was transiently unavailable (503) —
        the server's drain-estimate value, surfaced from the typed error.
        None when the request was not refused or has not resolved yet."""
        err = self._error
        if err is None and self._inner is not None:
            err = getattr(self._inner, "error", None)
            if err is None:
                err = getattr(self._inner, "_error", None)
        return getattr(err, "retry_after_s", None)


def _retry_after_of(e, detail: str) -> Optional[float]:
    """The server's suggested backoff: the exact float from the JSON body
    when present, else the integer-seconds ``Retry-After`` header."""
    try:
        return float(json.loads(detail).get("retry_after_s"))
    except (TypeError, ValueError):
        pass
    try:
        return float(e.headers.get("Retry-After"))
    except (TypeError, ValueError):
        return None


class _HttpFuture:
    """Duck-types RequestHandle for the HTTP transport: a worker thread owns
    the blocking round-trip.  ``cancel()`` is client-local best-effort (the
    server enforces the request's own deadline)."""

    def __init__(self, call: Callable[[], np.ndarray]):
        self.done = threading.Event()
        self._result: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self.quality = 1.0             # < 1.0: degraded partial combine
        self._thread = threading.Thread(target=self._run, args=(call,),
                                        daemon=True)
        self._thread.start()

    def _run(self, call):
        try:
            res = call()
            if isinstance(res, tuple):
                self._result, self.quality = res
            else:
                self._result = res
        except BaseException as e:
            self._error = e
        self.done.set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError("HTTP predict timed out")
        if self._cancelled:
            raise RequestCancelled("request cancelled client-side")
        if self._error is not None:
            raise self._error
        return self._result

    def cancel(self) -> bool:
        if self.done.is_set():
            return False
        self._cancelled = True
        return True


class EnsembleClient:
    """``f(X, options) -> Y`` over either transport, with optional caching.

    Exactly one of ``system`` (an :class:`InferenceSystem`) or ``url`` (an
    HTTP v2 server, e.g. ``"http://127.0.0.1:8600"``) must be given.
    ``options`` is the default descriptor for calls that pass none; a
    per-call ``options`` object replaces it wholesale (build variants with
    ``dataclasses.replace(client.default_options, ...)``)."""

    def __init__(self, system=None, *, url: Optional[str] = None,
                 cache: Optional[PredictionCache] = None,
                 options: Optional[PredictOptions] = None,
                 http_timeout: float = 600.0):
        if (system is None) == (url is None):
            raise ValueError("construct with exactly one of system= or url=")
        self.system = system
        self.url = url.rstrip("/") if url else None
        self.cache = cache
        self.default_options = options or PredictOptions()
        self.http_timeout = http_timeout

    # ---- call styles ---------------------------------------------------------
    def predict(self, X, options: Optional[PredictOptions] = None,
                timeout: float = 600.0) -> np.ndarray:
        """Sync style: blocks until the ensemble prediction is ready (or the
        request's deadline / ``timeout`` expires)."""
        return self.predict_async(X, options).result(timeout)

    def predict_async(self, X,
                      options: Optional[PredictOptions] = None) -> ClientHandle:
        """Async-handle style: returns immediately with a future."""
        opts = options or self.default_options
        X = np.asarray(X, np.int32)
        if self.cache is None or opts.cache == "bypass" or opts.stream:
            return ClientHandle(inner=self._submit(X, opts))
        salt = self._cache_salt(opts)
        if opts.cache == "refresh":    # recompute and overwrite entries
            return ClientHandle(inner=self._submit(X, opts), X_miss=X,
                                cache=self.cache, cache_salt=salt)
        cached, miss_idx = self.cache.lookup(X, salt)
        if not miss_idx:               # every row served from cache
            return ClientHandle(Y=np.stack(cached, axis=0))
        X_miss = X[miss_idx]
        return ClientHandle(inner=self._submit(X_miss, opts), cached=cached,
                            miss_idx=miss_idx, X_miss=X_miss,
                            cache=self.cache, cache_salt=salt)

    def predict_stream(self, X, on_segment: Callable,
                       options: Optional[PredictOptions] = None) -> ClientHandle:
        """Streaming-partials style: ``on_segment(s, lo, hi, Y_seg)`` fires
        as each segment's ensemble rows complete; ``result()`` still returns
        the full prediction.  In-process transport only (segment boundaries
        are not surfaced over HTTP), and the cache is bypassed so segment
        coordinates refer to ``X`` itself."""
        if self.system is None:
            raise ValueError("predict_stream requires the in-process "
                             "transport (construct with system=)")
        opts = replace(options or self.default_options, stream=True,
                       on_segment=on_segment, cache="bypass")
        return self.predict_async(X, opts)

    def _cache_salt(self, opts: PredictOptions) -> bytes:
        """A prediction is only reusable under the same ensemble config, so
        member subsets / combine rules partition the key space.  Normalized
        so semantically identical requests share a salt: members sort to a
        set, and (in-process, where the defaults are known) the full member
        set and the system's own combine rule collapse to None."""
        members = None if opts.members is None else \
            tuple(sorted(set(opts.members)))
        combine = opts.combine
        if self.system is not None:
            if members == tuple(range(self.system.M)):
                members = None
            if combine == self.system.combine:
                combine = None
        if members is None and combine is None:
            return b""
        return repr((members, combine)).encode()

    # ---- transports ----------------------------------------------------------
    def _submit(self, X: np.ndarray, opts: PredictOptions):
        if opts.stream and self.system is None:
            raise ValueError("streaming requires the in-process transport")
        if self.system is not None:
            return self.system.predict_async(X, options=opts)
        return _HttpFuture(lambda: self._http_predict(X, opts))

    def _http_predict(self, X: np.ndarray, opts: PredictOptions) -> np.ndarray:
        payload = {"tokens": X.tolist()}
        if priority_level(opts.priority) == PRIORITY_HIGH:
            payload["priority"] = "high"
        if opts.deadline_ms is not None:
            payload["deadline_ms"] = opts.deadline_ms
        if opts.members is not None:
            payload["members"] = list(opts.members)
        if opts.combine is not None:
            payload["combine"] = opts.combine
        if opts.cache != "use":
            payload["cache"] = opts.cache   # server-side cache policy
        try:
            r = self._http_json("POST", "/v2/predict", payload)
        except urllib.error.HTTPError as e:
            detail = e.read().decode(errors="replace")
            if e.code == 504:
                raise DeadlineExceeded(detail) from None
            if e.code == 429:
                # refused at admission (DESIGN.md §11): typed + the
                # server's drain-estimate backoff, so callers can shed or
                # retry elsewhere immediately
                raise Overloaded(
                    detail, retry_after_s=_retry_after_of(e, detail)) \
                    from None
            if e.code == 503:
                # transient capacity failure (DESIGN.md §10): the server
                # set Retry-After — the request is retryable, not broken
                err = MemberUnavailable(detail)
                err.retry_after_s = _retry_after_of(e, detail)
                raise err from None
            raise RuntimeError(f"/v2/predict failed ({e.code}): {detail}") \
                from None
        return (np.asarray(r["predictions"], np.float32),
                float(r.get("quality", 1.0)))

    def _http_json(self, method: str, path: str, payload=None):
        req = urllib.request.Request(
            self.url + path,
            data=None if payload is None else json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"}, method=method)
        with urllib.request.urlopen(req, timeout=self.http_timeout) as resp:
            return json.load(resp)

    # ---- observability -------------------------------------------------------
    def metrics(self) -> dict:
        """Serving counters/gauges (+ cache hit rates), whichever transport."""
        if self.system is not None:
            # same shape as the server's GET /metrics, so code written
            # against one transport reads the other
            ctl = self.system.controller
            return {"counters": self.system.serving_counters(),
                    "gauges": self.system.serving_gauges(),
                    "latency": self.system.latency_snapshot(),
                    "stages": self.system.stage_timings(),
                    "cache": ({"hits": self.cache.hits,
                               "misses": self.cache.misses}
                              if self.cache is not None else None),
                    "controller": ctl.stats() if ctl is not None else None}
        return self._http_json("GET", "/metrics")

    def dump_trace(self, path: Optional[str] = None) -> dict:
        """Chrome-trace / Perfetto JSON of the flight recorder (DESIGN.md
        §13), whichever transport (in-process ``system.tracer.export()`` or
        ``GET /v2/trace``).  With ``path`` the JSON is also written to disk
        — open it at https://ui.perfetto.dev or chrome://tracing."""
        if self.system is not None:
            trace = self.system.tracer.export()
        else:
            trace = self._http_json("GET", "/v2/trace")
        if path is not None:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace
