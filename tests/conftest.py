import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # no pytest.ini/pyproject in this repo; register the chaos marker here
    # so `-m chaos` selects the fault-injection suite without warnings
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection / recovery tests on simulated devices")
