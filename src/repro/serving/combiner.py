"""Device-resident partial ensemble combine (DESIGN.md §4).

Workers co-located on one device fold their weighted predictions into a
shared per-(request, segment) partial *on the device* and post **one**
``Message(s, None, partial, rid, count)`` per device per segment — instead of
one {s, m, P} message (and one device->host transfer) per member.  With M
members sharing a device this cuts accumulator traffic by up to M×.

How the flush trigger stays deterministic under coalescing: the broadcaster
assigns every (segment, model) pair to a *specific* worker instance
(round-robin striping across data-parallel instances, system.py), so at
``begin()`` time the system knows exactly how many member contributions each
device will produce for each segment.  The coalescing batcher may split one
member's segment across several batches, so contributions arrive as
row-ranges — the combiner therefore counts **rows, not messages**: a segment
flushes the moment ``members_on_device × segment_rows`` rows have been
folded, which is reached exactly once however the spans were packed.

Early-forward audit (chunk-granular pipeline, DESIGN.md §3): senders now
forward a (request, segment) the moment its last chunk materializes —
before the slot retires, and under priority reordering possibly *out of
segment order* and interleaved arbitrarily across members.  The row
arithmetic above is already order-free (each (segment, member) contributes
its rows exactly once, whenever it arrives), so nothing here changes; the
same holds for the `unexpect`/`expect_one` steal migration, which operates
on counts, not arrival order.

Combination rules are applied member-side, so the partial is always additive:
  mean/weighted  partial[lo:hi] += w_m · P_m[lo:hi]
  vote           partial[lo:hi] += w_vote · onehot(argmax P_m[lo:hi])
  pallas         partial[lo:hi]  = ensemble_combine(P_m[None], [w_m],
                 partial[lo:hi]) — the accumulate-into-partial Pallas kernel
                 variant, applied to the span's rows
and the accumulator's per-message work collapses to ``Y[lo:hi] += partial``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serving.metrics import StageTimers
from repro.serving.segments import Message, Request


class _SegPartial:
    __slots__ = ("acc", "rows")

    def __init__(self):
        self.acc = None        # np.ndarray or jax.Array (device-resident)
        self.rows = 0          # member-rows folded so far


class DeviceCombiner:
    """One per device hosting >= 1 worker.  ``add()`` is called from worker
    sender threads; a per-combiner lock serializes the fold bookkeeping (the
    device math itself is dispatched asynchronously)."""

    def __init__(self, name: str, prediction_queue: "queue.Queue[Message]",
                 timers: Optional[StageTimers] = None, tracer=None):
        self.name = name
        self.prediction_queue = prediction_queue
        self.timers = timers
        self.tracer = tracer
        self._tr_track = f"combine.{name}"
        # ring cached once: rings are cleared in place, never replaced
        self._tr_ring = tracer.ring(self._tr_track) \
            if tracer is not None else None
        self._lock = threading.Lock()
        # rid -> {s: (member contributions, expected member-rows)}
        self._expected: Dict[int, Dict[int, Tuple[int, int]]] = {}
        self._parts: Dict[Tuple[int, int], _SegPartial] = {}
        self.partials_posted = 0

    # ---- request lifecycle ---------------------------------------------------
    def begin(self, req: Request, expected: Dict[int, int]) -> None:
        """Register how many member contributions each segment of ``req``
        will see on this device.  The flush trigger is row-based: segment
        ``s`` completes after ``expected[s] * (end(s)-start(s))`` rows."""
        with self._lock:
            self._expected[req.rid] = {
                s: (n, n * (req.bounds(s)[1] - req.bounds(s)[0]))
                for s, n in expected.items() if n}

    def finish(self, rid: int) -> None:
        """Drop any state for a completed/failed request (idempotent)."""
        with self._lock:
            self._expected.pop(rid, None)
            for key in [k for k in self._parts if k[0] == rid]:
                del self._parts[key]

    # ---- expected-map migration (work stealing, DESIGN.md §8) ----------------
    def unexpect(self, req: Request, s: int) -> bool:
        """Remove ONE expected member contribution for (``req``, ``s``) — the
        inverse of one unit of :meth:`begin` — because its queued descriptor
        was re-routed to a data-parallel sibling on another device.  Returns
        False when the request is no longer tracked here (completed or torn
        down), in which case the caller must not register the expectation
        elsewhere.  If other members' rows already closed the reduced row
        count, the partial flushes immediately — exactly the message the
        accumulator would have seen had the stolen member never been striped
        to this device."""
        flush = None
        with self._lock:
            expected = self._expected.get(req.rid)
            if expected is None or s not in expected:
                return False
            count, want_rows = expected[s]
            lo, hi = req.bounds(s)
            count -= 1
            want_rows -= hi - lo
            part = self._parts.get((req.rid, s))
            if count <= 0:
                # no member left on this device: nothing can have been folded
                # (each (segment, member) routes to exactly one instance)
                self._parts.pop((req.rid, s), None)
                del expected[s]
            elif part is not None and part.rows >= want_rows:
                flush = (part, count)
                del self._parts[(req.rid, s)]
                del expected[s]
            else:
                expected[s] = (count, want_rows)
            if not expected:
                del self._expected[req.rid]
        if flush is not None:
            self._post(req.rid, s, *flush)
        return True

    def expect_one(self, req: Request, s: int) -> None:
        """Register one additional expected member contribution for
        (``req``, ``s``) — the destination side of a stolen descriptor."""
        lo, hi = req.bounds(s)
        with self._lock:
            expected = self._expected.setdefault(req.rid, {})
            count, want_rows = expected.get(s, (0, 0))
            expected[s] = (count + 1, want_rows + (hi - lo))

    # ---- the fold ------------------------------------------------------------
    def add(self, req: Request, s: int, m: int, P, row_lo: int = 0) -> None:
        """Fold member ``m``'s rows ``[row_lo, row_lo+len(P))`` of segment
        ``s`` into the device partial; post the partial once the segment's
        expected row count is reached.  ``P`` may be a numpy array (fake
        workers) or a device array — device arrays stay resident until the
        single flush transfer."""
        t0 = time.perf_counter()
        flush = None
        # quantized members forward (q, per-row scale) tuples
        nrows = int(P[0].shape[0]) if isinstance(P, tuple) else int(P.shape[0])
        # the heavy elementwise math runs outside the lock; only the
        # accumulate + bookkeeping is serialized
        contrib = self._contribution(req, P, req.weights[m])
        with self._lock:
            expected = self._expected.get(req.rid)
            if expected is None or s not in expected:   # request torn down
                return
            part = self._parts.setdefault((req.rid, s), _SegPartial())
            part.acc = self._fold(req, part.acc, contrib, req.weights[m],
                                  s, row_lo)
            part.rows += nrows
            count, want_rows = expected[s]
            if part.rows >= want_rows:
                flush = (part, count)
                del self._parts[(req.rid, s)]
                del expected[s]
                if not expected:
                    del self._expected[req.rid]
        if flush is not None:
            self._post(req.rid, s, *flush)
        t1 = time.perf_counter()
        if self.timers is not None:
            self.timers.add("combine", t1 - t0)
        tr = self.tracer
        if tr is not None and tr.enabled:
            self._tr_ring.append(
                ("X", "combine", t0, t1 - t0, req.rid,
                 s, m, flush is not None))

    def _post(self, rid: int, s: int, part: _SegPartial, count: int) -> None:
        """The single device->host transfer per device per segment."""
        self.prediction_queue.put(Message(
            s, None, np.asarray(part.acc), rid=rid, count=count))
        self.partials_posted += 1

    @staticmethod
    def _contribution(req: Request, P, w: float):
        """Member's additive contribution (weighted prediction / vote).  For
        the pallas rule the raw device array passes through: the weighting is
        fused into the accumulate kernel at fold time.  Quantized members
        forward ``(q, per-row scale)`` tuples — pallas defers dequantization
        to the fused epilogue kernel; vote uses ``q`` directly (the per-row
        scale is positive and uniform across classes, so argmax is
        preserved); mean/weighted dequantize here."""
        if isinstance(P, tuple):
            if req.combine == "pallas":
                return P
            from repro.kernels import quant as kq
            P = P[0] if req.combine == "vote" else kq.dequantize(P[0], P[1])
        if req.combine == "vote":
            if isinstance(P, np.ndarray):
                contrib = np.zeros((P.shape[0], req.num_classes), np.float32)
                contrib[np.arange(P.shape[0]), P.argmax(axis=1)] = w
                return contrib
            import jax
            return w * jax.nn.one_hot(P.argmax(axis=-1), req.num_classes,
                                      dtype=np.float32)
        if req.combine == "pallas" and not isinstance(P, np.ndarray):
            return P
        # mean / weighted (and pallas with host arrays from fake workers)
        return P * np.float32(w)

    @staticmethod
    def _fold(req: Request, acc, contrib, w: float, s: int, row_lo: int):
        """Fold a span contribution into the full-segment partial at its row
        offset.  The partial is allocated once per (request, segment) at the
        segment's full row count, host- or device-side matching the first
        contribution."""
        lo, hi = req.bounds(s)
        seg_rows = hi - lo
        quant = isinstance(contrib, tuple)     # (q, per-row scale) pair
        a = row_lo
        b = row_lo + int(contrib[0].shape[0] if quant else contrib.shape[0])
        if not quant and isinstance(contrib, np.ndarray):
            if acc is None:
                acc = np.zeros((seg_rows, req.num_classes), np.float32)
            acc[a:b] += contrib                # in-place: no temp per fold
            return acc
        import jax.numpy as jnp
        if acc is None:
            acc = jnp.zeros((seg_rows, req.num_classes), jnp.float32)
        if req.combine == "pallas":
            from repro.kernels import ops as kops
            if quant:
                # fused dequant-weight-accumulate epilogue: q stays in its
                # narrow storage dtype all the way into the kernel
                q, scale = contrib
                upd = kops.ensemble_accumulate_quant(
                    acc[a:b], q[None], scale.reshape(1, -1),
                    jnp.full((1,), w, jnp.float32))
            else:
                # the accumulate-into-partial Pallas kernel variant, on the
                # span
                upd = kops.ensemble_accumulate(
                    acc[a:b], contrib[None].astype(jnp.float32),
                    jnp.full((1,), w, jnp.float32))
            return acc.at[a:b].set(upd) if (a, b) != (0, seg_rows) else upd
        return acc.at[a:b].add(contrib) if (a, b) != (0, seg_rows) \
            else acc + contrib
