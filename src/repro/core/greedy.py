"""Algorithm 2 — Bounded greedy optimization (paper §II.E.2).

Starts from Algorithm 1's matrix; each iteration scores at most
``max_neighs`` randomly drawn single-element neighbours and moves to the best
strictly-improving one; stops at ``max_iter`` or on a plateau.  Worst case it
returns the starting matrix (inherited greedy guarantee).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.allocation import DEFAULT_BATCH_SIZES, AllocationMatrix
from repro.core.bench import Bench


@dataclass
class GreedyTrace:
    """History of one optimization run (EXPERIMENTS.md evidence)."""
    scores: List[float] = field(default_factory=list)
    evaluated: int = 0
    iterations: int = 0
    visited_rate: List[float] = field(default_factory=list)


def bounded_greedy(start: AllocationMatrix, bench: Bench, *,
                   max_iter: int = 10, max_neighs: int = 100,
                   batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
                   seed: int = 0) -> Tuple[AllocationMatrix, GreedyTrace]:
    rng = random.Random(seed)
    trace = GreedyTrace()

    # paper §III: when D - M > max_iter, give every device a chance to be used
    D, M = start.A.shape
    if D - M > max_iter:
        max_iter = D - M

    a = start.copy()
    a_speed = bench(a)
    trace.scores.append(a_speed)
    trace.evaluated += 1

    it = 0
    while it < max_iter:
        neighs = list(a.neighbors(batch_sizes))
        total = max(1, len(neighs))
        if len(neighs) > max_neighs:
            neighs = rng.sample(neighs, max_neighs)
        trace.visited_rate.append(len(neighs) / total)

        best_a, best_speed = None, a_speed
        for n in neighs:
            s = bench(n)
            trace.evaluated += 1
            if s > best_speed:
                best_a, best_speed = n, s

        if best_a is not None and best_speed > a_speed:
            a, a_speed = best_a, best_speed
            trace.scores.append(a_speed)
            it += 1
            trace.iterations = it
        else:
            break                      # local maximum (or plateau) detected
    return a, trace
