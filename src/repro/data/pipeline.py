"""Synthetic-but-learnable data pipeline for training and calibration.

Tasks:
  * "ngram": tokens follow a fixed random bigram table — a real learnable
    distribution (loss provably decreases toward the table's entropy).
  * "copy": second half of each sequence repeats the first half.
  * "uniform": i.i.d. tokens (calibration / benchmarking only).

The iterator yields host numpy batches; ``shard_batch`` places a global batch
onto a mesh with batch-axis sharding (used by launch/train.py).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, *, task: str = "ngram",
                 seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.task = task
        self.rng = np.random.default_rng(seed)
        if task == "ngram":
            # sparse-ish bigram table with temperature; rows sum to 1
            logits = self.rng.gumbel(size=(vocab, vocab)) * 2.0
            top = np.argsort(logits, axis=1)[:, -8:]          # 8 successors each
            probs = np.zeros((vocab, vocab), np.float64)
            rows = np.arange(vocab)[:, None]
            probs[rows, top] = self.rng.dirichlet(np.ones(8), size=vocab)
            self.table = probs

    def batch(self, batch_size: int) -> Dict[str, np.ndarray]:
        v, s = self.vocab, self.seq_len
        if self.task == "uniform":
            toks = self.rng.integers(0, v, (batch_size, s + 1))
        elif self.task == "copy":
            half = (s + 1) // 2 + 1
            first = self.rng.integers(0, v, (batch_size, half))
            toks = np.concatenate([first, first], axis=1)[:, :s + 1]
        elif self.task == "ngram":
            toks = np.empty((batch_size, s + 1), np.int64)
            toks[:, 0] = self.rng.integers(0, v, batch_size)
            cum = self.table.cumsum(axis=1)
            for t in range(1, s + 1):
                u = self.rng.random(batch_size)[:, None]
                toks[:, t] = (cum[toks[:, t - 1]] < u).sum(axis=1)
        else:
            raise ValueError(self.task)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iterator(self, batch_size: int, cfg: Optional[ModelConfig] = None
                 ) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch(batch_size)
            if cfg is not None and cfg.frontend_tokens:
                b["frontend"] = np.zeros(
                    (batch_size, cfg.frontend_tokens, cfg.fdim), np.float32)
            yield b


class PrefetchIterator:
    """Background-thread prefetch (double buffering) over a host iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        import queue
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        for item in self.it:
            if self._stop.is_set():
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()


def shard_batch(batch: Dict[str, np.ndarray], mesh, batch_axes=("data",)):
    """device_put a host batch with its leading dim sharded over batch_axes."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
