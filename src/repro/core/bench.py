"""``bench(A, calib_data) -> throughput`` — the greedy's scoring function.

Two backends (DESIGN.md §2/§9.1):

* ``MeasuredBench`` — the paper's: instantiate the inference system in
  Benchmark Mode on calibration samples and time it.  Used on this container
  with reduced models; on real hardware it is the ground truth.
* ``AnalyticBench`` — beyond-paper: a roofline cost model evaluated from the
  configs and device specs.  Scores a matrix in microseconds instead of the
  paper's ~40 s, letting the greedy visit far more neighbours.

Both return samples/sec, and 0.0 for infeasible matrices (paper's convention).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import memory as mem
from repro.core.allocation import AllocationMatrix

Bench = Callable[[AllocationMatrix], float]


def per_model_throughput(alloc: AllocationMatrix,
                         worker_time: Callable[[int, int, int], float]
                         ) -> list:
    """The shared cycle model: co-located workers time-share their device
    round-robin (a device's cycle time is the sum of its workers'
    latencies) and a model's throughput adds over its data-parallel
    instances.  ``worker_time(d, m, batch)`` supplies the per-batch latency
    — the roofline for :class:`AnalyticBench`, measured EWMAs for the
    serving layer's live bench — so the offline allocator and the online
    replanner score matrices under one cost model."""
    cycle = [0.0] * len(alloc.devices)
    for d, m, b in alloc.workers():
        cycle[d] += worker_time(d, m, b)
    per_model = [0.0] * len(alloc.model_names)
    for d, m, b in alloc.workers():
        per_model[m] += b / cycle[d]
    return per_model


class AnalyticBench:
    """Roofline throughput model.

    Worker latency per cycle: t = overhead + max(compute, memory) where
      compute = batch * seq * flops_per_token / peak_flops
      memory  = (params_bytes + batch * act_bytes) / mem_bw
    Co-located workers time-share their device round-robin: a device's cycle
    time is the sum of its workers' latencies, and a worker completes
    ``batch`` samples per cycle.  A model's throughput adds over its
    data-parallel instances; the ensemble's throughput is the min over models
    (every member must predict every sample).
    """

    def __init__(self, cfgs: Sequence[ModelConfig], *, seq: int = 128,
                 dtype_bytes: int = 4, overhead_s: float = 2e-4,
                 member_dtypes: Optional[Sequence[Optional[str]]] = None):
        self.cfgs = list(cfgs)
        self.seq = seq
        self.dtype_bytes = dtype_bytes
        self.overhead_s = overhead_s
        # per-member execution dtype (DESIGN.md §14): narrows both the
        # roofline's param-streaming term and the fit_mem footprint
        self.member_dtypes = list(member_dtypes) if member_dtypes else None
        self.calls = 0

    def bytes_moved(self, cfg: ModelConfig, batch: int,
                    member_dtype: Optional[str] = None) -> float:
        """The roofline's memory term: streamed param bytes (narrowed by the
        member dtype, DESIGN.md §14) plus fp32 activation traffic."""
        act_per_tok = (2 * cfg.d_model + (cfg.d_ff or 2 * cfg.d_model)) * self.dtype_bytes
        param_bytes = mem._param_bytes_per_elem(member_dtype, self.dtype_bytes)
        return (cfg.active_param_count() * param_bytes
                + batch * self.seq * act_per_tok)

    def worker_time(self, dev, cfg: ModelConfig, batch: int,
                    member_dtype: Optional[str] = None) -> float:
        flops = batch * self.seq * cfg.flops_per_token(self.seq)
        bytes_moved = self.bytes_moved(cfg, batch, member_dtype)
        return self.overhead_s + max(flops / dev.peak_flops,
                                     bytes_moved / dev.mem_bw)

    def _member_dtype(self, m: int) -> Optional[str]:
        return self.member_dtypes[m] if self.member_dtypes else None

    def __call__(self, alloc: AllocationMatrix) -> float:
        self.calls += 1
        if not alloc.is_valid():
            return 0.0
        if not mem.fit_mem(alloc, self.cfgs, self.seq, self.dtype_bytes,
                           member_dtypes=self.member_dtypes):
            return 0.0
        per_model = per_model_throughput(
            alloc, lambda d, m, b: self.worker_time(alloc.devices[d],
                                                    self.cfgs[m], b,
                                                    self._member_dtype(m)))
        return min(per_model)


class MeasuredBench:
    """The paper's offline benchmark: build the inference system for ``alloc``
    in Benchmark Mode, push the calibration samples through, time it."""

    def __init__(self, cfgs: Sequence[ModelConfig], params_list, calib_x,
                 *, segment_size: int = 128, repeats: int = 1,
                 frontends: Optional[dict] = None):
        self.cfgs = list(cfgs)
        self.params_list = params_list
        self.calib_x = calib_x
        self.segment_size = segment_size
        self.repeats = repeats
        self.frontends = frontends or {}
        self.calls = 0

    def __call__(self, alloc: AllocationMatrix) -> float:
        from repro.serving.system import InferenceSystem   # lazy: no cycle
        self.calls += 1
        if not alloc.is_valid():
            return 0.0
        if not mem.fit_mem(alloc, self.cfgs, self.calib_x.shape[1]):
            return 0.0
        try:
            system = InferenceSystem(self.cfgs, self.params_list, alloc,
                                     segment_size=self.segment_size,
                                     frontends=self.frontends)
        except MemoryError:
            return 0.0
        try:
            _, throughput = system.benchmark(self.calib_x, repeats=self.repeats)
        finally:
            system.shutdown()
        return throughput


class MemoBench:
    """Memoizing wrapper (beyond-paper): identical matrices are scored
    once.  The paper re-runs the 40 s benchmark on revisits."""

    def __init__(self, inner: Bench):
        self.inner = inner
        self.cache: Dict[str, float] = {}
        self.hits = 0

    def __call__(self, alloc: AllocationMatrix) -> float:
        k = alloc.key()
        if k in self.cache:
            self.hits += 1
            return self.cache[k]
        v = self.inner(alloc)
        self.cache[k] = v
        return v
