"""Algorithm 1 — Worst-Fit-Decreasing with priority to GPUs (paper §II.E.1).

Models sorted by decreasing memory size; each is placed (at the minimum batch
size) on the accelerator with the most remaining memory, falling back to the
CPU side only when no accelerator fits, and erroring when nothing fits.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import memory as mem
from repro.core.allocation import AllocationMatrix, zeros
from repro.core.devices import DeviceSpec


class AllocationError(RuntimeError):
    """Paper line 24: no device has enough memory."""


def _most_remaining(alloc: AllocationMatrix, cfgs, seq: int,
                    accelerator: bool, member_dtypes=None) -> int:
    remaining = mem.remaining_memory(alloc, cfgs, seq,
                                     member_dtypes=member_dtypes)
    best, best_rem = -1, -1
    for d, dev in enumerate(alloc.devices):
        if dev.is_accelerator != accelerator:
            continue
        if remaining[d] > best_rem:
            best, best_rem = d, remaining[d]
    return best


def worst_fit_decreasing(cfgs: Sequence[ModelConfig],
                         devices: List[DeviceSpec], *,
                         default_batch_size: int = 8,
                         seq: int = 128,
                         member_dtypes=None) -> AllocationMatrix:
    """Returns an allocation with every model placed exactly once.

    ``member_dtypes`` (one dtype name per model, None = fp32) makes the
    footprints dtype-size-aware: int8/fp8 members sort and pack at ~1/4 the
    fp32 param bytes, roughly doubling members per device (DESIGN.md §14).
    """
    names = [c.name for c in cfgs]
    alloc = zeros(devices, names)

    def mdt(m):
        return member_dtypes[m] if member_dtypes else None

    # sort models in descending order of memory size (offline heuristic)
    order = sorted(range(len(cfgs)),
                   key=lambda m: mem.worker_bytes(cfgs[m], default_batch_size,
                                                  seq, member_dtype=mdt(m)),
                   reverse=True)
    for m in order:
        placed = False
        for accelerator in (True, False):          # GPUs strictly first
            d = _most_remaining(alloc, cfgs, seq, accelerator, member_dtypes)
            if d < 0:
                continue
            cand = alloc.copy()
            cand.A[d, m] = default_batch_size
            if mem.fit_mem(cand, cfgs, seq, member_dtypes=member_dtypes):
                alloc = cand
                placed = True
                break
        if not placed:
            raise AllocationError(
                f"no device has enough memory for {names[m]} "
                f"(batch={default_batch_size})")
    alloc.validate()
    return alloc
