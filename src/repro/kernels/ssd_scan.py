"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Tiling: grid = (batch, num_chunks); the chunk dim is the innermost sequential
grid dim, so the inter-chunk SSM state (H, P, N) is carried in VMEM scratch
(f32).  Each kernel invocation computes one chunk's dual form:

    y_intra = (C B^T ∘ L) (dt x)        — attention-like, MXU matmuls
    y_inter = C h_in * exp(cumsum dA)   — contribution of the carried state
    h_out   = h_in * exp(sum dA) + B^T (dt decay x)

For mamba2-1.3b a full state tile is 64*64*128*4B = 2 MiB and a chunk tile is
~1 MiB — comfortably inside the ~16 MiB/core VMEM budget; chunk length 64
keeps the L matrix (cl, cl) MXU-aligned when padded to 128 (done by ops.py
only when cl < 8; default chunks are already aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, nheads: int,
            hdim: int, dstate: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)               # (cl, H, P)
    dt = dt_ref[0].astype(jnp.float32)             # (cl, H)
    A = a_ref[...].astype(jnp.float32)             # (H,)
    bm = b_ref[0].astype(jnp.float32)              # (cl, N)
    cm = c_ref[0].astype(jnp.float32)              # (cl, N)

    dA = dt * A[None, :]                           # (cl, H)
    cs = jnp.cumsum(dA, axis=0)                    # (cl, H)
    # intra-chunk: scores (cl, cl), decay L per head
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # (cl, cl)
    diff = cs[:, None, :] - cs[None, :, :]         # (i, j, H)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)              # (i, j, H)
    gated = scores[:, :, None] * L                                  # (i, j, H)
    xdt = x * dt[:, :, None]                                        # (j, H, P)
    y_intra = jnp.einsum("ijh,jhp->ihp", gated, xdt)
    # inter-chunk: apply carried state
    h_in = h_ref[...]                                               # (H, P, N)
    y_inter = jnp.einsum("in,hpn->ihp", cm, h_in) * jnp.exp(cs)[:, :, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    decay_to_end = jnp.exp(cs[-1:, :] - cs)                         # (j, H)
    new_state = jnp.einsum("jn,jhp->hpn", bm, xdt * decay_to_end[:, :, None])
    h_ref[...] = h_in * jnp.exp(cs[-1])[:, None, None] + new_state


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, bmat: jax.Array,
             cmat: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> jax.Array:
    """x: (B,S,H,P) f32, dt: (B,S,H) post-softplus, A: (H,) negative,
    bmat/cmat: (B,S,N).  S must be a multiple of ``chunk`` (ops.py pads).
    Returns y: (B,S,H,P) f32."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_kernel, nheads=h, hdim=p, dstate=n, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((h,), lambda b_, c_: (0,)),
            pl.BlockSpec((1, chunk, n), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, h, p), lambda b_, c_: (b_, c_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, bmat, cmat)
