"""Chunk-granular predictor pipeline tests (ISSUE 5): bit-identical results
vs the ``coalesce=False`` baseline, priority chunk ordering under a
saturated ring, refcount-correct slot recycling on CPU (aliased
``device_put``), quiesce/FLUSH barriers with chunks in the dispatch queue,
dropped-at-dequeue chunks of cancelled/expired requests, the deadline-aware
steal policy, and the per-class latency metrics."""
import queue
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.admission import AdmissionQueue, DispatchQueue, chunk_level
from repro.serving.segments import (ChunkDesc, DeadlineExceeded, FLUSH,
                                    PRIORITY_HIGH, PRIORITY_NORMAL,
                                    PredictOptions, Request, RequestCancelled,
                                    SlotRef, Span)
from repro.serving.system import InferenceSystem
from repro.serving.worker import RING_SLOTS, Worker

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


def _mk_request(n=16, priority=PRIORITY_NORMAL, deadline=None, rid=0):
    return Request(rid, np.zeros((n, SEQ), np.int32), n, 8, 16, [0],
                   {0: 1.0}, "mean", priority=priority, deadline=deadline)


# ---- unit: chunk level / dispatch queue / slot refcount ----------------------

def test_chunk_level_most_urgent_span_wins():
    hi = _mk_request(priority=PRIORITY_HIGH)
    lo = _mk_request(priority=PRIORITY_NORMAL)
    assert chunk_level([Span(lo, 0, 0, 0, 4)]) == PRIORITY_NORMAL
    assert chunk_level([Span(lo, 0, 0, 0, 4),
                        Span(hi, 0, 0, 4, 2)]) == PRIORITY_HIGH
    assert chunk_level([]) == PRIORITY_NORMAL


def test_dispatch_queue_high_chunks_jump_bulk():
    """High-priority chunks overtake queued bulk chunks, FIFO within a
    class; chunks are never stolen or migrated."""
    q = DispatchQueue()
    ref = SlotRef(None, np.zeros((8, SEQ), np.int32), 4)
    bulk = [ChunkDesc(ref, 0, 8, 8, [], PRIORITY_NORMAL) for _ in range(3)]
    hot = ChunkDesc(ref, 0, 8, 8, [], PRIORITY_HIGH)
    for c in bulk[:2]:
        q.put(c, c.level)
    q.put(hot, hot.level)
    q.put(bulk[2], bulk[2].level)
    order = [q.get_nowait() for _ in range(4)]
    assert order == [hot, bulk[0], bulk[1], bulk[2]]
    with pytest.raises(TypeError):
        q.steal(4)
    with pytest.raises(TypeError):
        q.drain_descriptors()


def test_slot_ref_release_exactly_once_owner():
    ref = SlotRef(2, np.zeros((8, SEQ), np.int32), 3)
    assert ref.pending == 3
    assert not ref.release()
    assert not ref.release()
    assert ref.release()          # the zero-crossing release owns recycling
    assert ref.pending == 0


# ---- bit-identical results under chunk reordering ----------------------------

@pytest.mark.parametrize("device_combine", [True, False])
def test_chunk_pipeline_bit_identical_vs_uncoalesced(ens2, device_combine):
    """Acceptance: ensemble outputs are bit-identical to the
    ``coalesce=False`` baseline under chunk-granular dispatch — including
    member subsets, mixed priorities (which reorder chunks), and the device
    combine.  Request sizes are multiples of the compiled batch so both
    schedules group the same rows into the same compiled shapes and the
    comparison is exact, not approximate."""
    cfgs, params = ens2
    rng = np.random.default_rng(7)
    sizes = [8, 16, 24, 8, 32, 16]
    member_sets = [[0, 1], [0], [1], [0, 1], [0], [0, 1]]
    Xs = [rng.integers(0, 512, (n, SEQ)).astype(np.int32) for n in sizes]

    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                     device_combine=device_combine, coalesce=False,
                     max_in_flight=6) as ref:
        Y_ref = [ref.predict(x, members=m, timeout=120.0)
                 for x, m in zip(Xs, member_sets)]

    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                     device_combine=device_combine, coalesce=True,
                     max_in_flight=6) as s:
        opts = [PredictOptions(priority="high" if i % 2 else "normal")
                for i in range(len(Xs))]
        handles = [s.predict_async(x, members=m, options=o)
                   for x, m, o in zip(Xs, member_sets, opts)]
        Ys = [h.result(120.0) for h in handles]
    for y, y_ref in zip(Ys, Y_ref):
        np.testing.assert_array_equal(y, y_ref)


# ---- priority chunk ordering under a saturated ring --------------------------

def test_high_priority_chunk_jumps_saturated_ring(ens2):
    """With every ring slot flushed full of bulk chunks (simulated device
    time makes the backlog deterministic), a late high-priority request
    completes well before the bulk drains — its chunk jumped the queued
    bulk chunks instead of waiting for RING_SLOTS slots."""
    cfgs, params = ens2
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=32,
                     fake=True, fake_delay_us=3000, coalesce=True,
                     max_in_flight=16, max_wait_us=100,
                     dispatch_ahead=2) as s:   # shallow committed window
        bulk = [s.predict_async(np.zeros((32, SEQ), np.int32))
                for _ in range(8)]          # 8 slots x 4 chunks x 3ms
        time.sleep(0.02)                    # let the ring saturate
        t0 = time.perf_counter()
        s.predict(np.zeros((8, SEQ), np.int32),
                  options=PredictOptions(priority="high"), timeout=60.0)
        hp_lat = time.perf_counter() - t0
        done_bulk = sum(h.done.is_set() for h in bulk)
        for h in bulk:
            h.result(60.0)
        st = s.stage_timings()
    # the bulk backlog is ~8x4x3ms of simulated device time; the high
    # request's chunk waits only for the committed window (~2 chunks)
    assert done_bulk < len(bulk) // 2, (hp_lat, done_bulk)
    assert hp_lat < 0.05, hp_lat
    assert st["dispatch_wait.high"]["mean_ms"] < \
        st["dispatch_wait.normal"]["mean_ms"]


# ---- refcount-correct slot recycling -----------------------------------------

def test_ring_slots_all_recycle_after_completion(ens2):
    """Every ring slot returns to the free list once its LAST chunk's
    output is materialized — with real models on CPU (`device_put` may
    alias the slot buffer), so corruption would show up as wrong results in
    the bit-identity test above, and leaks show up here."""
    cfgs, params = ens2
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=32,
                     coalesce=True, max_in_flight=8) as s:
        handles = [s.predict_async(
            np.random.default_rng(i).integers(0, 512, (24, SEQ))
            .astype(np.int32)) for i in range(8)]
        for h in handles:
            h.result(120.0)
        deadline = time.perf_counter() + 10.0
        for w in s.workers:
            while w._free_slots.qsize() < RING_SLOTS:
                assert time.perf_counter() < deadline, "slot leaked"
                time.sleep(0.005)
            assert w._free_slots.qsize() == RING_SLOTS


# ---- quiesce barriers with chunks in the dispatch queue ----------------------

def test_quiesce_barrier_with_queued_chunks(ens2):
    """quiesce(wait=True) must ack only after flushed chunks have been
    dispatched, and must not deadlock while the dispatch queue is deep with
    slow simulated-device chunks; the system keeps serving afterwards."""
    cfgs, params = ens2
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=32,
                     fake=True, fake_delay_us=2000, coalesce=True,
                     max_in_flight=8, max_wait_us=30_000_000,
                     dispatch_ahead=2) as s:
        handles = [s.predict_async(np.zeros((32, SEQ), np.int32))
                   for _ in range(4)]
        h_tail = s.predict_async(np.zeros((3, SEQ), np.int32))  # lingering
        assert s.quiesce(wait=True, timeout=30.0)
        for h in handles + [h_tail]:
            np.testing.assert_array_equal(h.result(30.0), 0)
        # re-entrant: quiesce/submit cycles stay legal on the chunk pipeline
        h2 = s.predict_async(np.zeros((5, SEQ), np.int32))
        assert s.quiesce(wait=True, timeout=30.0)
        np.testing.assert_array_equal(h2.result(30.0), 0)


# ---- dropped-at-dequeue chunks (cancelled / expired requests) ----------------

def _stall_predictor(monkeypatch, worker_ids):
    release = threading.Event()
    orig = Worker._predictor

    def stalling(self):
        if self.worker_id in worker_ids:
            release.wait(60.0)
        return orig(self)

    monkeypatch.setattr(Worker, "_predictor", stalling)
    return release


def test_cancelled_request_chunks_dropped_at_dequeue(ens2, monkeypatch):
    """A cancelled request's already-flushed chunks are dropped when
    dequeued — rows land on the DROPPED accounting path (`rows_dropped`),
    no device dispatch happens for them, the ring slots still recycle, and
    the worker keeps serving."""
    cfgs, params = ens2
    release = _stall_predictor(monkeypatch, {"w0.0"})
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=32,
                     fake=True, coalesce=True, max_in_flight=8,
                     max_wait_us=100) as s:
        try:
            h = s.predict_async(np.zeros((32, SEQ), np.int32))
            deadline = time.perf_counter() + 10.0
            while s.workers[0].dispatch_backlog() == 0:   # chunks flushed
                assert time.perf_counter() < deadline
                time.sleep(0.002)
            assert h.cancel()
            with pytest.raises(RequestCancelled):
                h.result(10.0)
        finally:
            release.set()
        # the stalled predictor now drains the queue: chunks are skipped
        deadline = time.perf_counter() + 10.0
        while s.serving_counters().get("rows_dropped", 0) < 32:
            assert time.perf_counter() < deadline, s.serving_counters()
            time.sleep(0.005)
        assert s.serving_counters()["rows_dropped"] == 32
        np.testing.assert_array_equal(          # slot recycled; still serving
            s.predict(np.zeros((8, SEQ), np.int32), timeout=30.0), 0)
        for w in s.workers:
            deadline = time.perf_counter() + 10.0
            while w._free_slots.qsize() < RING_SLOTS:
                assert time.perf_counter() < deadline, "slot leaked"
                time.sleep(0.005)


def test_expired_request_chunks_dropped_at_dequeue(ens2, monkeypatch):
    """A request whose deadline expires after its rows were packed (chunks
    already in the dispatch queue) resolves with DeadlineExceeded via the
    dequeue-time DROPPED path instead of occupying device time."""
    cfgs, params = ens2
    release = _stall_predictor(monkeypatch, {"w0.0"})
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=32,
                     fake=True, coalesce=True, max_in_flight=8,
                     max_wait_us=100) as s:
        try:
            h = s.predict_async(np.zeros((32, SEQ), np.int32),
                                options=PredictOptions(deadline_ms=150.0))
            deadline = time.perf_counter() + 10.0
            while s.workers[0].dispatch_backlog() == 0:
                assert time.perf_counter() < deadline
                time.sleep(0.002)
            time.sleep(0.2)                  # let the deadline lapse
        finally:
            release.set()
        with pytest.raises(DeadlineExceeded):
            h.result(10.0)
        deadline = time.perf_counter() + 10.0
        while s.serving_counters().get("rows_dropped", 0) < 32:
            assert time.perf_counter() < deadline, s.serving_counters()
            time.sleep(0.005)


# ---- deadline-aware steal policy (ROADMAP item i) ----------------------------

def test_steal_prefers_tightest_deadline():
    """Within the stealable tail region, descriptors with the tightest
    remaining deadline budget are selected (and returned) first;
    deadline-less descriptors rank loosest, newest first; sentinels still
    fence the sweep."""
    now = time.perf_counter()
    loose = _mk_request(deadline=now + 10.0, rid=1)
    tight = _mk_request(deadline=now + 0.5, rid=2)
    mid = _mk_request(deadline=now + 2.0, rid=3)
    none = _mk_request(deadline=None, rid=4)
    q = AdmissionQueue()
    for req in (loose, none, tight, mid):
        q.put((req, 0))
    assert [r.rid for r, _ in q.steal(3)] == [2, 3, 1]    # tightest first
    assert q.get_nowait()[0].rid == 4                     # loosest stays
    # sentinels fence the stealable region even for tight deadlines
    q2 = AdmissionQueue()
    q2.put((tight, 0))
    q2.put(FLUSH)
    q2.put((loose, 1))
    assert [r.rid for r, _ in q2.steal(8)] == [1]
    # no deadlines at all: classic newest-first tail steal, order preserved
    q3 = AdmissionQueue()
    items = [(_mk_request(rid=i), 0) for i in range(5)]
    for it in items:
        q3.put(it)
    assert q3.steal(2) == items[3:]


# ---- per-class latency metrics ----------------------------------------------

def test_latency_snapshot_and_hp_gauge(ens2):
    cfgs, params = ens2
    with make_system(cfgs[:1], params[:1], np.array([[8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=100) as s:
        for i in range(4):
            s.predict(np.zeros((4, SEQ), np.int32), timeout=30.0,
                      options=PredictOptions(
                          priority="high" if i % 2 else "normal"))
        lat = s.latency_snapshot()
        assert set(lat) == {"high", "normal"}
        for cls in lat:
            assert lat[cls]["n"] == 2
            assert 0 < lat[cls]["p50_ms"] <= lat[cls]["p99_ms"]
        assert s.serving_gauges()["hp_p50_ms"]["last"] > 0
