"""End-to-end request tracing (DESIGN.md §13).

A lock-light span layer recording per-request causal timelines at chunk
granularity: admission → batcher slot-pack → dispatch-queue wait → predict
→ transfer → combine/accumulate.  Every pipeline stage emits flat event
fields into a bounded per-track :class:`FlightRecorder` ring (drop-oldest
``deque`` — the emit is one GIL-atomic C call, so the hot path takes no
lock, retains no GC-tracked object, and pays one attribute check when
tracing is disabled).  Rings are created lazily under a small lock the
first time a track emits.

Events reuse timestamps the pipeline already computes (``chunk.t_enq``,
``Request.t_submit``, the ``StageTimers.timed`` return value), and the
per-chunk dispatch-wait record is stored grouped per dispatch round
("G" below), so tracing adds one C-level append, not allocation or
clock calls, per chunk — the ``tracing_overhead`` bench gates the total
at <= 5%.

The clock is pluggable: the live system uses ``time.perf_counter``; the
discrete-event simulator passes ``lambda: loop.now`` so a recorded trace
replayed live and in-sim produces directly comparable timelines (both
exports rebase to their first event).

:meth:`Tracer.export` renders the Chrome-trace / Perfetto JSON event
format (``traceEvents`` with ``ph "X"`` complete spans, ``ph "i"``
instants and ``ph "M"`` track-name metadata; ``ts``/``dur`` in
microseconds) — load it at https://ui.perfetto.dev or chrome://tracing.

:meth:`Tracer.anomaly` snapshots the flight recorder into a bounded dump
list tagged with its trigger (watchdog stall, deadline-miss burst,
brownout level change, RetriesExhausted), so the window of spans *leading
up to* a fault survives even after the ring wraps.
"""
from __future__ import annotations

import struct
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["FlightRecorder", "Tracer", "pack_times"]

# Emitted events are 8 flat fields: (ph, name, t0_s, dur_s, rid, a, b, c)
#   ph    "X" complete span | "i" instant | "G"/"g" grouped records
#   rid   int, tuple of ints (multi-request chunks), or None
#   a,b,c positional args: scalars keyed by _SLOT_KEYS[name] at decode
#         time, or a dict in slot ``a`` (cold paths), or packed bytes in
#         slot ``a`` for "G"/"g"
#
# Storage is FLAT — the ring deque holds the 8 fields themselves, not an
# event tuple.  This is the core of the near-zero-overhead story: a
# retained tuple per event is tracked by the cyclic GC from birth, and a
# busy tracer allocates enough of them to multiply young-generation
# collections and trigger periodic FULL-heap scans (tens of ms each next
# to a JAX runtime — measured, that alone blew the 5% overhead budget).
# Flat fields are floats/strs/ints/bytes the GC never counts or scans,
# and the transient 8-tuple passed to ``deque.extend`` nets zero on the
# collector's allocation counters.
#
# "G" is the compact form for the highest-volume record (per-chunk
# dispatch_wait): the predictor stores ONE rid-free event per pop round —
# the dur slot holds the ABSOLUTE pop time, slot ``a`` the per-chunk
# enqueue times packed with :func:`pack_times`, and slots ``b``/``c``
# the attached round predict duration / committed-chunk count.  "g" is
# the single-span variant (sender transfer): a normal (t0, dur) span
# whose slot ``a`` carries the group's enqueue times purely for request
# attribution.
#
# Neither grouped form extracts request ids on the hot path.  Request
# attribution is recovered at export time by JOINING each chunk's
# ``t_enq`` against the same worker's batcher "pack" instants: a flush
# stamps one shared ``t_enq`` (a perf_counter float — collision-free
# across flushes) on its chunks AND on the pack instant that records the
# flushed rid set, and chunks never migrate between dispatch queues
# (steal/replay re-route one stage earlier and re-flush), so
# ``(worker, t_enq) -> rids`` is exact.  A pack instant that fell off a
# wrapped ring resolves to no rid — bounded-recorder semantics.
#
# Decoded form (what ``Tracer.tracks`` returns): (ph, name, t0, dur,
# rid, args) with args a dict or None; "G"/"g" args carry the unpacked
# ``t_enq`` tuple.
_Event = Tuple[str, str, float, float, Any, Any]

_PH = ("X", "i", "G", "g")
_STRIDE = 8

# positional-arg key names by event name (hot emitters pass scalars in
# slots a/b/c instead of allocating a dict per event)
_SLOT_KEYS = {
    "pack": ("chunks", "level"),
    "predict": ("chunks",),
    "transfer": ("chunks",),
    "dropped": ("s",),
    "forgive_demoted": ("s",),
    "combine": ("s", "m", "posted"),
    "accumulate": ("s", "rows"),
}


# struct.Struct cache keyed by element count: skips the per-call format
# string build + parse (the emitter sees a handful of distinct group sizes)
_STRUCTS: Dict[int, struct.Struct] = {}


def _struct_for(n: int) -> struct.Struct:
    s = _STRUCTS.get(n)
    if s is None:
        s = _STRUCTS[n] = struct.Struct(f"<{n}d")
    return s


def pack_times(ts) -> bytes:
    """Encode a timestamp sequence as bytes for the "G" record's
    enqueue-times slot (bytes are invisible to the cyclic GC)."""
    return _struct_for(len(ts)).pack(*ts)


def _decode(ph, name, t0, dur, rid, a, b, c) -> _Event:
    """Flat ring fields -> (ph, name, t0, dur, rid, args)."""
    if ph == "G":
        args = {"t_enq": _struct_for(len(a) // 8).unpack(a)}
        if b is not None:
            args["predict_dur"] = b
        if c is not None:
            args["chunks"] = c
        return ph, name, t0, dur, rid, args
    if ph == "g":
        if isinstance(a, bytes):        # packed enqueue times inline
            return ph, name, t0, dur, rid, {
                "t_enq": _struct_for(len(a) // 8).unpack(a), "chunks": b}
        return ph, name, t0, dur, rid, {"t_pop": a, "chunks": b}
    if a is None:
        return ph, name, t0, dur, rid, None
    if isinstance(a, dict):
        return ph, name, t0, dur, rid, a
    keys = _SLOT_KEYS.get(name, ("a", "b", "c"))
    return ph, name, t0, dur, rid, {
        k: v for k, v in zip(keys, (a, b, c)) if v is not None}


def _matches(erid, rid) -> bool:
    return erid == rid or (isinstance(erid, tuple) and rid in erid)


def _pack_rid_maps(tracks) -> Dict[str, Dict[float, Any]]:
    """``worker -> {flush t_enq: rid(s)}`` from the batcher pack instants
    — the attribution source grouped "G"/"g" records join against."""
    maps: Dict[str, Dict[float, Any]] = {}
    for tid, events in tracks.items():
        if not tid.endswith("/batcher"):
            continue
        m = maps.setdefault(tid[:-len("/batcher")], {})
        for _ph, name, t0, _dur, rid, _args in events:
            if name == "pack":
                m[t0] = rid
    return maps


def _round_maps(tracks) -> Dict[str, Dict[float, tuple]]:
    """``worker -> {round pop time: chunk t_enq tuple}`` from the "G"
    dispatch-round records — the second join hop for "g" records that
    carry only the round's pop-time correlation key."""
    maps: Dict[str, Dict[float, tuple]] = {}
    for tid, events in tracks.items():
        if not tid.endswith("/predict"):
            continue
        m = maps.setdefault(tid[:-len("/predict")], {})
        for ph, _name, _t0, dur, _rid, args in events:
            if ph == "G":               # dur slot = absolute pop time
                m[dur] = args["t_enq"]
    return maps


def _rid_union(m: Dict[float, Any], ts) -> Any:
    """Distinct request ids a group of chunk enqueue times resolves to."""
    rids = set()
    for t in ts:
        r = m.get(t)
        if isinstance(r, tuple):
            rids.update(r)
        elif r is not None:
            rids.add(r)
    if not rids:
        return None
    return rids.pop() if len(rids) == 1 else tuple(sorted(rids))


class FlightRecorder:
    """Bounded drop-oldest ring of trace events for one track.

    ``append`` takes one 8-field event tuple ``(ph, name, t0, dur, rid,
    a, b, c)`` and is bound directly to the underlying ``deque.extend``
    (a C builtin that never yields the GIL mid-call) — the hot path pays
    no Python frame, takes no lock, and retains no GC-tracked object:
    the argument tuple is transient and only its scalar fields survive
    in the ring.  ``snapshot`` re-chunks the flat stream, recovering
    stride alignment by locating the ph column (a copy taken while a
    full ring wraps mid-extend can start mid-event; event names are
    never 1-char ph markers, so the alignment is unambiguous).
    """

    __slots__ = ("_ring", "capacity", "append")

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=_STRIDE * self.capacity)
        self.append = self._ring.extend    # C-level, per-event hot path

    def __len__(self) -> int:
        return len(self._ring) // _STRIDE

    def snapshot(self) -> List[tuple]:
        """Aligned raw 8-field events, oldest first."""
        for _ in range(8):
            try:
                raw = list(self._ring)
            except RuntimeError:        # writer appended mid-copy: retry
                continue
            if len(raw) < _STRIDE:
                return []
            for off in range(_STRIDE):
                idx = range(off, len(raw) - _STRIDE + 1, _STRIDE)
                if all(type(raw[j]) is str and raw[j] in _PH for j in idx):
                    return [tuple(raw[j:j + _STRIDE]) for j in idx]
            # no offset validated: copy torn by a concurrent wrap, retry
        return []

    def clear(self) -> None:
        self._ring.clear()


class Tracer:
    """Per-system span recorder with per-track flight-recorder rings.

    Hot-path contract: emitters check ``tracer.enabled`` first (one
    attribute read when off) and may cache ``tracer.ring(tid)`` per
    thread, appending event tuples directly — ``span``/``instant`` are
    the convenience forms for cold paths.
    """

    def __init__(self, enabled: bool = True, capacity: int = 4096, *,
                 clock: Callable[[], float] = time.perf_counter,
                 max_dumps: int = 8, burst_n: int = 8,
                 burst_window_s: float = 1.0):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._rings: Dict[str, FlightRecorder] = {}
        self._anomalies: deque = deque(maxlen=64)
        self._dumps: deque = deque(maxlen=max_dumps)
        self._burst_window = float(burst_window_s)
        self._miss_t: deque = deque(maxlen=max(2, burst_n))
        self._last_burst = -float("inf")

    # ---- emission ------------------------------------------------------------
    def ring(self, tid: str) -> FlightRecorder:
        """Get-or-create the track's ring (locks only on first use)."""
        r = self._rings.get(tid)
        if r is None:
            with self._lock:
                r = self._rings.setdefault(tid, FlightRecorder(self.capacity))
        return r

    def span(self, tid: str, name: str, t0: float, t1: float,
             rid=None, args: Optional[dict] = None) -> None:
        if self.enabled:
            self.ring(tid).append(
                ("X", name, t0, t1 - t0, rid, args, None, None))

    def instant(self, tid: str, name: str, t: Optional[float] = None,
                rid=None, args: Optional[dict] = None) -> None:
        if self.enabled:
            if t is None:
                t = self.clock()
            self.ring(tid).append(("i", name, t, 0.0, rid, args, None, None))

    # ---- anomaly-triggered dumps --------------------------------------------
    def anomaly(self, trigger: str, detail: str = "",
                args: Optional[dict] = None) -> Optional[dict]:
        """Record an anomaly and freeze a flight-recorder snapshot tagged
        with the trigger.  Returns the dump (or None when disabled)."""
        if not self.enabled:
            return None
        t = self.clock()
        info = {"trigger": trigger, "detail": detail, "t": t}
        if args:
            info.update(args)
        self._anomalies.append(info)
        self.ring("anomalies").append(
            ("i", trigger, t, 0.0, None, {"detail": detail}, None, None))
        dump = self.export()
        dump["metadata"]["dump_trigger"] = dict(info)
        self._dumps.append(dump)
        return dump

    def note_deadline_miss(self) -> None:
        """Per-miss hook with burst detection: ``burst_n`` misses inside
        ``burst_window_s`` fire one rate-limited anomaly dump."""
        if not self.enabled:
            return
        t = self.clock()
        m = self._miss_t
        m.append(t)
        if (len(m) == m.maxlen and t - m[0] <= self._burst_window
                and t - self._last_burst > self._burst_window):
            self._last_burst = t
            self.anomaly("deadline_miss_burst",
                         f"{m.maxlen} deadline misses in {t - m[0]:.3f}s")

    def dumps(self) -> List[dict]:
        return list(self._dumps)

    def anomalies(self) -> List[dict]:
        return list(self._anomalies)

    # ---- inspection ----------------------------------------------------------
    def tracks(self) -> Dict[str, List[_Event]]:
        with self._lock:
            items = list(self._rings.items())
        return {tid: [_decode(*ev) for ev in r.snapshot()]
                for tid, r in items}

    def timeline(self, rid: int) -> List[Tuple[str, str, str, float, float]]:
        """All events touching request ``rid`` as
        ``(track, ph, name, t0, dur)`` sorted by start time — the
        connected admission→combine view of one request.  Grouped
        records resolve per-chunk attribution through the pack-instant
        join (see the storage notes at the top of this module)."""
        out = []
        tracks = self.tracks()
        maps = _pack_rid_maps(tracks)
        rounds = _round_maps(tracks)
        for tid, events in tracks.items():
            w = tid.rsplit("/", 1)[0]
            m = maps.get(w, {})
            rm = rounds.get(w, {})
            for ph, name, t0, dur, erid, args in events:
                if ph == "G":           # one span per grouped chunk
                    ts = args["t_enq"]
                    if erid is not None:    # emitter attributed eagerly
                        mine = ts if _matches(erid, rid) else ()
                    else:
                        mine = [t for t in ts if _matches(m.get(t), rid)]
                    # dur slot holds the round's absolute pop time
                    out.extend((tid, "X", name, t, dur - t) for t in mine)
                    if mine and args.get("predict_dur") is not None:
                        out.append((tid, "X", "predict", dur,
                                    args["predict_dur"]))
                    continue
                if ph == "g":
                    ts = args.get("t_enq")
                    if ts is None:
                        ts = rm.get(args.get("t_pop"), ())
                    er = erid if erid is not None else _rid_union(m, ts)
                    if _matches(er, rid):
                        out.append((tid, "X", name, t0, dur))
                    continue
                if _matches(erid, rid):
                    out.append((tid, ph, name, t0, dur))
        out.sort(key=lambda e: (e[3], e[1] != "X"))
        return out

    def clear(self) -> None:
        with self._lock:
            rings = list(self._rings.values())
        for r in rings:
            r.clear()
        self._miss_t.clear()

    # ---- Chrome-trace / Perfetto export -------------------------------------
    def export(self, *, process_name: str = "serving") -> dict:
        """Render every track as Chrome-trace JSON (ts/dur in µs, rebased
        to the earliest recorded event so live and virtual-clock runs
        line up at t=0)."""
        tracks = self.tracks()
        maps = _pack_rid_maps(tracks)
        rounds = _round_maps(tracks)
        base = min((ev[2] for events in tracks.values() for ev in events),
                   default=0.0)
        trace_events: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": process_name},
        }]

        def rid_args(rid) -> Dict[str, Any]:
            if isinstance(rid, tuple):
                return {"rids": list(rid)}
            return {} if rid is None else {"rid": rid}

        for tno, tid in enumerate(sorted(tracks), start=1):
            trace_events.append({
                "ph": "M", "name": "thread_name", "pid": 0, "tid": tno,
                "args": {"name": tid},
            })
            trace_events.append({
                "ph": "M", "name": "thread_sort_index", "pid": 0, "tid": tno,
                "args": {"sort_index": tno},
            })
            w = tid.rsplit("/", 1)[0]
            m = maps.get(w, {})
            rm = rounds.get(w, {})
            for ph, name, t0, dur, rid, args in tracks[tid]:
                if ph == "G":           # expand to one "X" span per chunk
                    ts = args["t_enq"]
                    trace_events.extend({
                        "ph": "X", "name": name, "cat": "serving",
                        "pid": 0, "tid": tno, "ts": 1e6 * (t - base),
                        "dur": 1e6 * (dur - t),
                        "args": rid_args(rid if rid is not None
                                         else m.get(t)),
                    } for t in ts)
                    if args.get("predict_dur") is not None:
                        a = rid_args(rid if rid is not None
                                     else _rid_union(m, ts))
                        a["chunks"] = args.get("chunks")
                        trace_events.append({
                            "ph": "X", "name": "predict", "cat": "serving",
                            "pid": 0, "tid": tno, "ts": 1e6 * (dur - base),
                            "dur": 1e6 * args["predict_dur"], "args": a,
                        })
                    continue
                if ph == "g":           # grouped single span
                    ts = args.get("t_enq")
                    if ts is None:
                        ts = rm.get(args.get("t_pop"), ())
                    a = rid_args(rid if rid is not None
                                 else _rid_union(m, ts))
                    a["chunks"] = args.get("chunks")
                    trace_events.append({
                        "ph": "X", "name": name, "cat": "serving",
                        "pid": 0, "tid": tno, "ts": 1e6 * (t0 - base),
                        "dur": 1e6 * dur, "args": a,
                    })
                    continue
                ev: Dict[str, Any] = {
                    "ph": ph, "name": name, "cat": "serving",
                    "pid": 0, "tid": tno,
                    "ts": 1e6 * (t0 - base),
                }
                a = dict(args) if args else {}
                a.update(rid_args(rid))
                if ph == "X":
                    ev["dur"] = 1e6 * dur
                else:
                    ev["s"] = "t"       # thread-scoped instant
                if a:
                    ev["args"] = a
                trace_events.append(ev)
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "metadata": {
                "clock": ("virtual" if getattr(
                    self.clock, "__name__", "<lambda>") == "<lambda>"
                    else self.clock.__name__),
                "base_s": base,
                "anomalies": list(self._anomalies),
            },
        }
