"""Paper Table I: ensemble throughput vs number of devices, Algorithm 1 alone
(A1) vs Algorithm 1 + bounded greedy (A2).

Two modes mirroring the paper's 16-GPU HGX grid on this CPU container:
  * measured — real InferenceSystem runs of reduced ensembles on 1..3
    logical devices backed by the host CPU;
  * analytic — the full 1..16-GPU grid with the roofline bench on simulated
    V100s (the paper's hardware), reproducing the table's *shape*:
    throughput grows with devices, OOM ('-') when the ensemble can't fit.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import ensemble
from repro.core import (AllocationOptimizer, AnalyticBench, MeasuredBench,
                        host_cpus, simulated_gpus)
from repro.core.worst_fit import AllocationError

GiB = 1024 ** 3


def analytic_grid(rows=("ENS1", "ENS4", "ENS12"),
                  gpu_counts=(1, 2, 3, 4, 6, 8, 12, 16), seq: int = 128,
                  gpu_mem_frac: float = 0.08):
    """GPU memory is sized so the big ensembles OOM ('-') on few devices,
    reproducing Table I's shape (e.g. IMN12 needs >=4 GPUs, CIF36 >=5)."""
    out = []
    for name in rows:
        cfgs = ensemble(name)
        for g in gpu_counts:
            devices = simulated_gpus(g, memory_bytes=int(gpu_mem_frac * GiB)) + \
                host_cpus(1, memory_bytes=int(0.05 * GiB))
            bench = AnalyticBench(cfgs, seq=seq)
            try:
                opt = AllocationOptimizer(cfgs, devices, bench, max_iter=10,
                                          max_neighs=100, seq=seq)
                res = opt.optimize()
                out.append((name, g, round(res.wfd_score, 1),
                            round(res.final_score, 1),
                            res.trace.evaluated))
            except AllocationError:
                out.append((name, g, "-", "-", 0))
    return out


def measured_grid(device_counts=(1, 2), n_samples=128, seq=16, seed=0):
    import jax
    import repro.models as M
    out = []
    rng = jax.random.PRNGKey(seed)
    cfgs = ensemble("ENS4")
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    calib = np.random.default_rng(0).integers(
        0, cfgs[0].vocab_size, (n_samples, seq)).astype(np.int32)
    for d in device_counts:
        devices = host_cpus(d, memory_bytes=4 * GiB)
        bench = MeasuredBench(cfgs, params, calib, segment_size=32)
        opt = AllocationOptimizer(cfgs, devices, bench, max_iter=1,
                                  max_neighs=6, batch_sizes=(8, 16, 32),
                                  seq=seq)
        res = opt.optimize()
        out.append(("ENS4-measured", d, round(res.wfd_score, 1),
                    round(res.final_score, 1), res.trace.evaluated))
    return out


def run(csv=True):
    rows = analytic_grid()
    rows += measured_grid()
    if csv:
        print("table1:ensemble,devices,A1_throughput,A2_throughput,#bench")
        for r in rows:
            print("table1:" + ",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run()
