"""Process-wide lowering flags.

UNROLL_SCANS: when True, every structural lax.scan (layer stack, chunked
attention) is fully unrolled at trace time.  XLA's ``cost_analysis()`` counts
a while-loop body exactly once regardless of trip count, so the dry-run /
roofline lowers with unrolled scans to get truthful FLOP/byte totals; the
deployable path keeps scans (compact HLO, fast compiles).
"""
UNROLL_SCANS = False

# Sharding-variant knobs for the perf hillclimb (EXPERIMENTS.md §Perf).
# Set via set_variant(); consulted by parallel/sharding.py and models/moe.py.
#   moe_constraints: mesh | None — explicit GShard expert-parallel sharding
#       constraints inside the MoE dispatch/combine einsums.
#   attn_replicate_small_heads: replicate attention projections when
#       num_heads doesn't divide the model axis (instead of head_dim sharding).
#   decode_cache_seq: shard decode KV caches along sequence (flash-decoding).
SHARDING_OPTS = {
    "moe_constraints": None,
    "attn_replicate_small_heads": False,
    "decode_cache_seq": False,
    "seq_parallel": None,          # mesh -> shard activations' seq dim over
                                   # "model" between layers (Megatron-SP)
    "remat_policy": None,          # None = full remat; "dots" = save matmul
                                   # outputs (skips recomputing dots + the
                                   # collectives attached to them in bwd)
    "fsdp_params": False,          # ZeRO-3: shard params + opt state over
                                   # "data" too (see sharding._add_fsdp)
    "kv_quant": False,             # int8 KV cache (decode shapes)
}

VARIANTS = {
    "baseline": {},
    "moe_ep": {"moe_constraints": "mesh"},          # mesh filled at lower time
    "attn_repl": {"attn_replicate_small_heads": True},
    "cache_seqshard": {"decode_cache_seq": "mesh"},
    "seq_par": {"seq_parallel": "mesh"},
    "attn_repl+seq_par": {"attn_replicate_small_heads": True,
                          "seq_parallel": "mesh"},
    "attn_repl+moe_ep": {"attn_replicate_small_heads": True,
                         "moe_constraints": "mesh"},
    "attn_repl+remat_dots": {"attn_replicate_small_heads": True,
                             "remat_policy": "dots"},
    "fsdp": {"fsdp_params": True},
    "kv_int8": {"kv_quant": True},
    "kv_int8+combined": {"kv_quant": True,
                         "attn_replicate_small_heads": True},
    "attn_repl+fsdp": {"attn_replicate_small_heads": True,
                       "fsdp_params": True},
    "attn_repl+fsdp+remat_dots": {"attn_replicate_small_heads": True,
                                  "fsdp_params": True,
                                  "remat_policy": "dots"},
    "combined": {"moe_constraints": "mesh",
                 "attn_replicate_small_heads": True,
                 "decode_cache_seq": "mesh"},
}


def set_variant(name: str, mesh=None) -> None:
    opts = dict(VARIANTS[name])
    for k in ("moe_constraints", "seq_parallel", "decode_cache_seq"):
        if opts.get(k) == "mesh":
            opts[k] = mesh
    base = {"moe_constraints": None, "attn_replicate_small_heads": False,
            "decode_cache_seq": False, "seq_parallel": None,
            "remat_policy": None, "fsdp_params": False, "kv_quant": False}
    base.update(opts)
    SHARDING_OPTS.clear()
    SHARDING_OPTS.update(base)


def scan_unroll() -> bool:
    return UNROLL_SCANS


def set_unroll(value: bool) -> None:
    global UNROLL_SCANS
    UNROLL_SCANS = bool(value)
