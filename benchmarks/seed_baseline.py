"""The SEED serving hot path, vendored verbatim-in-spirit for A/B benchmarks.

This module preserves the pre-hot-path-rewrite implementation (commit
``e46b2aa``, trimmed to what the fake-model overhead benchmark exercises) so
``benchmarks/serving_hotpath.py`` can measure the new engine against the real
"before", not a weakened flag on the new engine:

  * per-batch ``np.concatenate`` padding and per-chunk allocation in the
    batcher (no ring buffers, no shape buckets);
  * one {s, m, P} message and one device->host sync per member per segment
    (no device-resident partial combine);
  * a single shared-X buffer and a single-request accumulator — ``predict()``
    calls fully serialize (no request ids, no in-flight window).

Do not use this for serving; it exists only as a measurement baseline.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocation import AllocationMatrix
from repro.serving import segments as seg
from repro.serving.segments import DEFAULT_SEGMENT_SIZE, SHUTDOWN, Message


class SeedWorker:
    """Seed worker, fake-predictor path only (zeros per batch chunk)."""

    def __init__(self, worker_id: str, cfg: ModelConfig, batch_size: int,
                 input_queue: "queue.Queue", prediction_queue: "queue.Queue",
                 model_idx: int, shared_x: np.ndarray):
        self.worker_id = worker_id
        self.batch_size = batch_size
        self.model_idx = model_idx
        self.input_queue = input_queue
        self.prediction_queue = prediction_queue
        self.shared_x = shared_x
        self.num_classes = cfg.vocab_size
        self._batch_q: "queue.Queue" = queue.Queue(maxsize=4)
        self._send_q: "queue.Queue" = queue.Queue(maxsize=8)
        self._threads: List[threading.Thread] = []
        self.prediction_queue.put(Message(seg.READY, model_idx, None))

    def start(self):
        for fn, name in [(self._batcher, "batcher"), (self._predictor, "predictor"),
                         (self._sender, "sender")]:
            t = threading.Thread(target=fn, name=f"{self.worker_id}-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def join(self, timeout: float = 30.0):
        for t in self._threads:
            t.join(timeout)

    def _batcher(self):
        while True:
            item = self.input_queue.get()
            if item == SHUTDOWN:
                self._batch_q.put(None)
                return
            s, nb_samples = item
            lo = seg.start(s, 128)
            hi = seg.end(s, 128, nb_samples)
            data = self.shared_x[lo:hi]
            batches = []
            for i in range(0, len(data), self.batch_size):
                chunk = data[i:i + self.batch_size]
                n = len(chunk)
                if n < self.batch_size:        # pad to the compiled shape
                    chunk = np.concatenate(
                        [chunk, np.zeros((self.batch_size - n,) + chunk.shape[1:],
                                         chunk.dtype)])
                batches.append((chunk, n))
            self._batch_q.put((s, hi - lo, batches))

    def _predictor(self):
        while True:
            item = self._batch_q.get()
            if item is None:
                self._send_q.put(None)
                return
            s, total, batches = item
            outs = [(np.zeros((self.batch_size, self.num_classes), np.float32), n)
                    for _, n in batches]       # fake predictor
            self._send_q.put((s, total, outs))

    def _sender(self):
        while True:
            item = self._send_q.get()
            if item is None:
                return
            s, total, outs = item
            parts = [np.asarray(y)[:n] for y, n in outs]   # sync point
            P = np.concatenate(parts, axis=0)
            assert P.shape[0] == total
            self.prediction_queue.put(Message(s, self.model_idx, P))


class SeedAccumulator:
    """Seed single-request accumulator, mean rule."""

    def __init__(self, prediction_queue: "queue.Queue", num_models: int):
        self.q = prediction_queue
        self.M = num_models
        self.weights = np.full(num_models, 1.0 / num_models, np.float32)
        self.ready_count = 0
        self.all_ready = threading.Event()
        self._expected_ready_count = None
        self._thread: Optional[threading.Thread] = None
        self.Y: Optional[np.ndarray] = None
        self.nb_samples = 0
        self._remaining = 0
        self.done = threading.Event()
        self.data_messages = 0

    def begin(self, nb_samples: int, num_classes: int, members: List[int]):
        self._members = members
        self.Y = np.zeros((nb_samples, num_classes), np.float32)
        self.nb_samples = nb_samples
        self._remaining = seg.num_segments(nb_samples, 128) * len(members)
        self.done.clear()

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError("seed accumulator timed out")
        return self.Y

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self):
        self.q.put(None)
        if self._thread:
            self._thread.join(10.0)

    def expect_ready(self, n: int):
        self._expected_ready_count = n
        if self.ready_count >= n:
            self.all_ready.set()

    def _run(self):
        while True:
            msg = self.q.get()
            if msg is None:
                return
            if msg.s == seg.READY:
                self.ready_count += 1
                if self.ready_count >= (self._expected_ready_count or 1):
                    self.all_ready.set()
                continue
            lo = seg.start(msg.s, 128)
            hi = seg.end(msg.s, 128, self.nb_samples)
            self.data_messages += 1
            self.Y[lo:hi] += msg.P * self.weights[msg.m]
            self._remaining -= 1
            if self._remaining == 0:
                self.done.set()


class SeedSystem:
    """Seed inference system: shared X buffer, per-model queues, serialized
    requests.  Fake predictors only (the overhead-measurement configuration)."""

    segment_size = DEFAULT_SEGMENT_SIZE

    def __init__(self, cfgs: Sequence[ModelConfig], alloc: AllocationMatrix,
                 *, max_seq: int = 128):
        alloc.validate()
        self.cfgs = list(cfgs)
        self.M = len(self.cfgs)
        self.num_classes = cfgs[0].vocab_size
        self.shared_x = np.zeros((self.segment_size, max_seq), np.int32)
        self.prediction_queue: "queue.Queue" = queue.Queue()
        self.model_queues: List[queue.Queue] = [queue.Queue() for _ in cfgs]
        self.accumulator = SeedAccumulator(self.prediction_queue, self.M)
        self.workers: List[SeedWorker] = []
        for d, m, batch in alloc.workers():
            w = SeedWorker(f"w{d}.{m}", self.cfgs[m], batch,
                           self.model_queues[m], self.prediction_queue, m,
                           self.shared_x)
            self.workers.append(w)
        self.accumulator.expect_ready(len(self.workers))
        self.accumulator.start()
        for w in self.workers:
            w.start()
        self.accumulator.all_ready.wait(60.0)
        self._shutdown = False

    def predict(self, X: np.ndarray, timeout: float = 600.0) -> np.ndarray:
        X = np.asarray(X, np.int32)
        n = X.shape[0]
        if n > self.shared_x.shape[0] or X.shape[1] != self.shared_x.shape[1]:
            self.shared_x = np.zeros((max(n, self.shared_x.shape[0]),
                                      X.shape[1]), np.int32)
            for w in self.workers:
                w.shared_x = self.shared_x
        self.shared_x[:n] = X
        members = list(range(self.M))
        self.accumulator.begin(n, self.num_classes, members)
        for s in range(seg.num_segments(n, self.segment_size)):
            for m in members:
                self.model_queues[m].put((s, n))
        return self.accumulator.wait(timeout)

    def shutdown(self):
        if self._shutdown:
            return
        self._shutdown = True
        for m, q in enumerate(self.model_queues):
            for _ in [w for w in self.workers if w.model_idx == m]:
                q.put(SHUTDOWN)
        for w in self.workers:
            w.join()
        self.accumulator.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
