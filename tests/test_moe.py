"""MoE layer invariants: dense vs capacity equivalence, load-balance loss,
routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models.moe import load_balance_loss, moe_ffn, moe_ffn_dense
import repro.models.transformer as T


def _setup(impl="dense", capacity_factor=1.25, experts=4, top_k=2):
    cfg = get_config("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, impl=impl, capacity_factor=capacity_factor,
        num_experts=experts, top_k=top_k))
    lp_shapes = T._layer_param_shapes(cfg, "attn")
    rng = jax.random.PRNGKey(0)
    lp = {}
    for i, (k, s) in enumerate(lp_shapes.items()):
        if k in ("router", "w_gate", "w_up", "w_down", "ws_gate", "ws_up",
                 "ws_down"):
            lp[k] = jax.random.normal(jax.random.fold_in(rng, i), s) * 0.05
    return cfg, lp


def test_capacity_matches_dense_when_ample():
    """With capacity >= group size no tokens drop: the GShard dispatch must
    equal the dense dropless computation exactly."""
    cfg_d, lp = _setup(impl="dense")
    cfg_c = dataclasses.replace(cfg_d, moe=dataclasses.replace(
        cfg_d.moe, impl="capacity", capacity_factor=float(cfg_d.moe.num_experts)))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 24, cfg_d.d_model))
    out_d, aux_d = moe_ffn(cfg_d, lp, x)
    out_c, aux_c = moe_ffn(cfg_c, lp, x)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                               atol=1e-5)
    assert abs(float(aux_d) - float(aux_c)) < 1e-6


def test_capacity_drops_reduce_output_norm():
    """Tight capacity drops tokens — outputs must differ from dense."""
    cfg_d, lp = _setup(impl="dense")
    cfg_tight = dataclasses.replace(cfg_d, moe=dataclasses.replace(
        cfg_d.moe, impl="capacity", capacity_factor=0.25))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, cfg_d.d_model))
    out_d, _ = moe_ffn(cfg_d, lp, x)
    out_t, _ = moe_ffn(cfg_tight, lp, x)
    assert float(jnp.abs(out_d - out_t).max()) > 1e-4


def test_load_balance_loss_bounds():
    """Uniform routing -> loss ~= 1; collapsed routing -> loss ~= E."""
    E, T_, k = 8, 1024, 2
    rng = np.random.default_rng(0)
    probs_u = np.full((T_, E), 1.0 / E, np.float32)
    idx_u = np.stack([rng.permutation(E)[:k] for _ in range(T_)])
    l_u = float(load_balance_loss(jnp.asarray(probs_u), jnp.asarray(idx_u), E))
    assert abs(l_u - k) < 0.2        # f sums to k with top-k counts

    probs_c = np.zeros((T_, E), np.float32)
    probs_c[:, 0] = 1.0
    idx_c = np.zeros((T_, k), np.int64)
    l_c = float(load_balance_loss(jnp.asarray(probs_c), jnp.asarray(idx_c), E))
    assert l_c > l_u * 2             # collapse penalized


def test_topk_weights_normalized():
    from repro.models.moe import _router
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 8)) * 0.1
    weights, idx, probs = _router(x, w, 3)
    np.testing.assert_allclose(np.asarray(weights.sum(-1)), 1.0, atol=1e-5)
    assert idx.shape == (32, 3)
    assert int(idx.max()) < 8


def test_shared_expert_always_on():
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    assert cfg.moe.shared_expert
    assert cfg.moe.top_k == 1
