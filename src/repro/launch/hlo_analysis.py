"""Parse collective traffic and op statistics out of (S)HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's third
term comes from summing the output-shard sizes of every collective op in the
post-SPMD HLO (shapes there are already per-device shard shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g.  bf16[4,128,64]{2,1,0}  or  f32[]
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

# computation block headers, e.g. "%body.123 (arg: bf16[..]) -> (..) {"
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->[^{]*\{",
                      re.M)
# while ops carry condition=%c, body=%b
_WHILE_RE = re.compile(r"while\([^)]*\)\s*,?\s*condition=%?([\w.\-]+)\s*,"
                       r"\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (brace matched from each header)."""
    out: Dict[str, str] = {}
    for m in _COMP_RE.finditer(hlo_text):
        name = m.group(1)
        i = hlo_text.index("{", m.start())
        depth, j = 0, i
        while j < len(hlo_text):
            if hlo_text[j] == "{":
                depth += 1
            elif hlo_text[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        out[name] = hlo_text[i:j + 1]
    return out


def while_trip_counts(hlo_text: str) -> Dict[str, int]:
    """body-computation name -> inferred trip count.

    XLA while conditions compare the induction variable to a constant; the
    largest integer constant in the condition computation is the trip count.
    Used to correct collective totals for lax.scan layer stacks (the HLO
    prints a while body once regardless of trip count).
    """
    comps = _split_computations(hlo_text)
    trips: Dict[str, int] = {}
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.groups()
        consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
        if consts:
            trips[body] = max(max(consts), 1)
    return trips


def collective_bytes(hlo_text: str, *, scale_while_bodies: bool = True
                     ) -> Dict[str, int]:
    """Per-collective-type bytes (output shard shapes) and op counts.

    With ``scale_while_bodies`` the bytes of collectives living inside a
    while body are multiplied by the loop's inferred trip count, so a
    scanned layer stack reports full totals.
    """
    comps = _split_computations(hlo_text)
    trips = while_trip_counts(hlo_text) if scale_while_bodies else {}
    # nested whiles: propagate multipliers one level (outer * inner)
    mult: Dict[str, int] = {}
    for body, t in trips.items():
        mult[body] = t
    for body, t in list(mult.items()):
        inner = comps.get(body, "")
        for m in _WHILE_RE.finditer(inner):
            _, inner_body = m.groups()
            if inner_body in trips:
                mult[inner_body] = trips[inner_body] * t

    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)

    def scan_block(text: str, factor: int):
        for m in _OP_LINE.finditer(text):
            shapes_str, op = m.groups()
            if "-done(" in m.group(0):
                continue
            total = 0
            if shapes_str.startswith("("):
                for sm in _SHAPE_RE.finditer(shapes_str):
                    total += shape_bytes(sm.group(0))
            else:
                total = shape_bytes(shapes_str)
            out[op] += total * factor
            counts[op] += 1

    body_names = set(mult)
    for name, text in comps.items():
        scan_block(text, mult.get(name, 1))
    # text outside known computations (rare) is ignored; ENTRY is in comps
    if not comps:                               # fallback: flat scan
        scan_block(hlo_text, 1)
    return {"bytes": dict(out), "counts": dict(counts),
            "total_bytes": sum(out.values()),
            "while_trip_counts": {k: v for k, v in trips.items()}}


def op_histogram(hlo_text: str, top: int = 15) -> Dict[str, int]:
    """Crude op-name histogram — used to spot remat recompute / fusion shape."""
    ops = re.findall(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w\-]*)\(", hlo_text)
    hist: Dict[str, int] = defaultdict(int)
    for o in ops:
        hist[o] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
