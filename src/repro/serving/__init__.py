"""The asynchronous inference system (paper §II): segment broadcaster,
worker pool, prediction accumulator, HTTP wrapper."""
from repro.serving.accumulator import PredictionAccumulator, RequestHandle
from repro.serving.combiner import DeviceCombiner
from repro.serving.metrics import StageTimers
from repro.serving.segments import DEFAULT_SEGMENT_SIZE, Message, Request
from repro.serving.server import AdaptiveBatcher, serve
from repro.serving.system import InferenceSystem
from repro.serving.worker import Worker, bucket_for, make_predict_fn

__all__ = ["InferenceSystem", "Worker", "make_predict_fn", "bucket_for",
           "Message", "Request", "RequestHandle", "PredictionAccumulator",
           "DeviceCombiner", "StageTimers", "AdaptiveBatcher", "serve",
           "DEFAULT_SEGMENT_SIZE"]
