"""Two-level priority admission queue (DESIGN.md §7, ROADMAP item a).

The worker batcher's input queue: latency-sensitive requests must not wait
behind a bulk scan, so admission is class-based instead of strict FIFO —
``PRIORITY_HIGH`` descriptors drain before ``PRIORITY_NORMAL`` ones, FIFO
*within* each class (no reordering among equals, so the sender's in-order
span-reassembly assumption still holds per (request, segment): all of one
segment's spans are packed in one batcher iteration either way).

The interface mirrors the ``queue.Queue`` subset the batcher uses
(``put`` / ``get(timeout)`` / ``get_nowait`` / ``qsize``) so control
sentinels (``SHUTDOWN`` / ``FLUSH``) flow through unchanged at normal
priority.  Starvation is not a concern at this queue's time scale: high
priority is meant for sparse latency-sensitive traffic, and a saturating
high-priority flood is an admission-control problem upstream of the worker.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from typing import Optional

from repro.serving.segments import PRIORITY_HIGH, PRIORITY_NORMAL


class AdmissionQueue:
    """Unbounded two-level MPSC queue with ``queue.Queue``-style blocking."""

    def __init__(self):
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._levels = {PRIORITY_HIGH: deque(), PRIORITY_NORMAL: deque()}

    def put(self, item, priority: int = PRIORITY_NORMAL) -> None:
        with self._not_empty:
            self._levels[priority].append(item)
            self._not_empty.notify()

    def _pop(self):
        for level in (PRIORITY_HIGH, PRIORITY_NORMAL):
            q = self._levels[level]
            if q:
                return q.popleft()
        raise queue.Empty

    def get(self, timeout: Optional[float] = None):
        with self._not_empty:
            if timeout is None:
                while not self._size_locked():
                    self._not_empty.wait()
            elif not self._not_empty.wait_for(self._size_locked, timeout):
                raise queue.Empty
            return self._pop()

    def get_nowait(self):
        with self._lock:
            return self._pop()

    def _size_locked(self) -> int:
        return len(self._levels[PRIORITY_HIGH]) + \
            len(self._levels[PRIORITY_NORMAL])

    def qsize(self) -> int:
        with self._lock:
            return self._size_locked()

    def steal(self, max_items: int) -> list:
        """Pop up to ``max_items`` of the NEWEST normal-priority segment
        descriptors off the tail, preserving their relative order (DESIGN.md
        §8: cross-worker work stealing).  Tail-stealing takes the work that
        would otherwise wait longest and leaves the victim's head untouched,
        so descriptors the batcher is about to drain are never contended.
        The sweep walks tail-ward until it meets a non-descriptor item and
        stops there: it can only take descriptors enqueued *after* the last
        sentinel, and a queue whose tail IS a sentinel (``SHUTDOWN`` /
        ``FLUSH`` just posted — the worker is draining or being quiesced)
        yields nothing.  Sentinels themselves are never popped or reordered.
        Atomic with respect to the consumer: a descriptor is owned either by
        the thief or by the batcher, never both."""
        with self._lock:
            q = self._levels[PRIORITY_NORMAL]
            stolen = []
            while q and len(stolen) < max_items and isinstance(q[-1], tuple):
                stolen.append(q.pop())
        stolen.reverse()
        return stolen

    def drain_descriptors(self) -> list:
        """Pop EVERY queued segment descriptor, both priority classes
        (drain-side instance migration — unlike :meth:`steal`, a retiring
        worker's latency-sensitive work must move too, or exactly the
        high-priority class would pay the victim's full drain latency).
        High-priority descriptors first, FIFO within each class; re-putting
        with each request's own priority restores class order at the
        destination.  Sentinels (``SHUTDOWN``/``FLUSH``/barriers) stay in
        place in their relative order — the retiring batcher still owes
        their acknowledgements."""
        out = []
        with self._lock:
            for level in (PRIORITY_HIGH, PRIORITY_NORMAL):
                keep = deque()
                for item in self._levels[level]:
                    (out if isinstance(item, tuple) else keep).append(item)
                self._levels[level] = keep
        return out

    def depth(self, priority: int) -> int:
        """Backlog of one class (the ``queue_depth.<worker>`` gauge uses
        ``qsize``; per-class depth feeds tests and adaptive linger)."""
        with self._lock:
            return len(self._levels[priority])
