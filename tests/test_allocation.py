"""Unit tests for the paper's core: allocation matrix, Algorithm 1, Algorithm
2, Eq. 1/2, BBS baseline, optimizer cache."""
import numpy as np
import pytest

from repro.configs import ensemble
from repro.core import (AllocationMatrix, AllocationOptimizer, AnalyticBench,
                        MemoBench, best_batch_strategy, bounded_greedy,
                        host_cpus, simulated_gpus, worst_fit_decreasing, zeros)
from repro.core.allocation import DEFAULT_BATCH_SIZES
from repro.core.bbs import BBSError, analytic_single_bench
from repro.core.worst_fit import AllocationError
from repro.core import memory as mem

GiB = 1024 ** 3


@pytest.fixture
def ens4():
    return ensemble("ENS4")


def test_matrix_validity(ens4):
    devs = simulated_gpus(3)
    names = [c.name for c in ens4]
    a = zeros(devs, names)
    assert not a.is_valid()                  # all-zero columns illegal
    a.A[:, :] = 8
    assert a.is_valid()
    a.A[:, 2] = 0
    assert not a.is_valid()
    a.A[0, 2] = 16
    assert a.is_valid()
    a.A[1, :] = 0                            # idle device row is legal
    assert a.is_valid()


def test_eq1_decision_space():
    # paper example: 8 DNNs, 4 GPUs + 1 CPU, B=5 -> ~1.3e31
    total = AllocationMatrix.total_matrices(D=5, M=8, B=5)
    assert 1.2e31 < total < 1.4e31


def test_eq2_neighborhood(ens4):
    # paper example: total_neighs = (B+1)*(D*M) - F with 232..240 for D=5, M=8
    devs = simulated_gpus(4) + host_cpus(1)
    names = [f"m{i}" for i in range(8)]
    a = zeros(devs, names)
    a.A[0, :] = 8                            # every model once on gpu0
    n = a.total_neighbors()
    assert 232 <= n <= 240
    # enumerated neighbours are all valid and differ in exactly one cell
    for cand in a.neighbors(DEFAULT_BATCH_SIZES):
        assert cand.is_valid()
        assert (cand.A != a.A).sum() == 1


def test_worst_fit_places_all(ens4):
    devs = simulated_gpus(4, memory_bytes=2 * GiB) + host_cpus(1, 8 * GiB)
    alloc = worst_fit_decreasing(ens4, devs)
    alloc.validate()
    assert alloc.num_workers() == 4
    assert mem.fit_mem(alloc, ens4, 128)
    # GPU priority: CPU unused while GPUs have room
    assert alloc.A[-1].sum() == 0


def test_worst_fit_colocates_when_fewer_devices(ens4):
    devs = simulated_gpus(2, memory_bytes=4 * GiB)
    alloc = worst_fit_decreasing(ens4, devs)
    alloc.validate()
    assert max(len(alloc.colocated(d)) for d in range(2)) >= 2


def test_worst_fit_oom(ens4):
    devs = simulated_gpus(1, memory_bytes=20 * 1024 ** 2)
    with pytest.raises(AllocationError):
        worst_fit_decreasing(ens4, devs)


def test_worst_fit_spills_to_cpu(ens4):
    devs = simulated_gpus(1, memory_bytes=70 * 1024 ** 2) + \
        host_cpus(1, 16 * GiB)
    alloc = worst_fit_decreasing(ens4, devs)
    assert alloc.A[1].sum() > 0              # CPU used once GPU is full


def test_greedy_improves_and_is_monotone(ens4):
    devs = simulated_gpus(4, memory_bytes=2 * GiB) + host_cpus(1, 8 * GiB)
    bench = MemoBench(AnalyticBench(ens4, seq=128))
    start = worst_fit_decreasing(ens4, devs)
    best, trace = bounded_greedy(start, bench, max_iter=10, max_neighs=60)
    assert trace.scores == sorted(trace.scores)      # monotone improvement
    assert bench(best) >= bench(start)               # never worse (paper)
    assert best.is_valid()


def test_greedy_max_iter_extension():
    """paper §III: when D - M > max_iter, max_iter grows to D - M."""
    cfgs = ensemble("ENS1")
    devs = simulated_gpus(16, memory_bytes=2 * GiB)
    bench = AnalyticBench(cfgs, seq=128)
    start = worst_fit_decreasing(cfgs, devs)
    best, trace = bounded_greedy(start, bench, max_iter=3, max_neighs=200)
    # ENS1 on 16 GPUs: data-parallelism should spread well beyond 3 iterations
    assert trace.iterations > 3
    assert best.instances(0)


def test_optimizer_cache_roundtrip(tmp_path, ens4):
    devs = simulated_gpus(4, memory_bytes=2 * GiB)
    bench = AnalyticBench(ens4, seq=128)
    cache = str(tmp_path / "alloc_cache.json")
    opt1 = AllocationOptimizer(ens4, devs, bench, max_iter=2, max_neighs=20,
                               cache_path=cache)
    r1 = opt1.optimize()
    assert not r1.from_cache
    opt2 = AllocationOptimizer(ens4, devs, bench, max_iter=2, max_neighs=20,
                               cache_path=cache)
    r2 = opt2.optimize()
    assert r2.from_cache
    assert np.array_equal(r1.matrix.A, r2.matrix.A)


def test_bbs_requires_enough_devices(ens4):
    with pytest.raises(BBSError):
        best_batch_strategy(ens4, simulated_gpus(2),
                            analytic_single_bench())


def test_bbs_vs_optimizer(ens4):
    """Our optimizer must beat or match BBS (paper Table III)."""
    devs = simulated_gpus(4, memory_bytes=2 * GiB) + host_cpus(1, 8 * GiB)
    bench = MemoBench(AnalyticBench(ens4, seq=128))
    bbs_alloc, nbench = best_batch_strategy(ens4, devs,
                                            analytic_single_bench(seq=128))
    assert nbench == len(ens4) * len(DEFAULT_BATCH_SIZES)
    opt = AllocationOptimizer(ens4, devs, bench, max_iter=10, max_neighs=100)
    res = opt.optimize()
    assert res.final_score >= bench(bbs_alloc)


def test_memory_model_monotone(ens4):
    c = ens4[0]
    b8 = mem.worker_bytes(c, 8, 128)
    b128 = mem.worker_bytes(c, 128, 128)
    assert b128 > b8 > c.param_count() * 4
