"""Segment protocol (paper §II.C.1) and the per-request descriptor.

Requests are split into fixed-size segments; only small descriptors flow
through the FIFO queues while the sample bytes live in the request's input
buffer.  Special ids: ``SHUTDOWN`` asks a worker to exit, ``FLUSH`` asks its
batcher to close any partially-filled coalesced batch immediately (quiesce);
workers emit ``Message(OOM/READY, ...)`` sentinels to the prediction
accumulator.

Hot-path extensions (DESIGN.md §3):
  * every in-flight request owns a :class:`Request` descriptor carrying a
    *versioned* input buffer — a new request never reallocates a buffer a
    worker may still be reading (the seed's ``shared_x`` swap race);
  * messages are tagged with the request id ``rid`` so multiple requests can
    be in flight at once;
  * a message with ``m is None`` is a *device partial*: the weighted sum of
    ``count`` member predictions, pre-combined on one device
    (DESIGN.md §4) — the accumulator just adds it into Y;
  * under the coalescing scheduler one compiled batch carries rows from
    *multiple* (request, segment) pairs — a :class:`Span` is one contiguous
    row-range of one segment inside one batch, and a batch's span list is
    the *scatter descriptor* the sender walks to route output rows back to
    their requests.  A segment's rows may therefore arrive split across
    several messages: ``Message.row_lo`` locates a message's rows inside the
    segment, and downstream accounting counts **rows, not messages**.

Request API (DESIGN.md §7): a :class:`PredictOptions` descriptor expresses
per-request intent — priority class, deadline, member subset, combine rule,
cache policy, streaming — and rides on the :class:`Request`, so every stage
(admission queue, batcher, combiner, accumulator) can honor it.  A batcher
that pops a descriptor whose request is cancelled or past its deadline posts
``Message(DROPPED, ...)`` instead of packing rows; the accumulator turns that
into a :class:`DeadlineExceeded` / :class:`RequestCancelled` result.

Chunk granularity (DESIGN.md §3): a flushed slot is no longer indivisible —
the batcher cuts it into its compiled chunks and each becomes a
:class:`ChunkDesc`, the unit the per-worker dispatch queue schedules (a
high-priority chunk jumps queued bulk chunks).  A :class:`SlotRef` carries
the slot's outstanding-chunk refcount: the ring buffer recycles only after
EVERY chunk's output is materialized (on CPU ``device_put`` may alias host
memory, so one chunk retiring early must not free rows another chunk still
reads).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

SHUTDOWN = -1          # segment-ids-queue sentinel: worker must exit
FLUSH = -3             # segment-ids-queue sentinel: flush open coalesced batch
OOM = -1               # prediction-queue sentinel: device out of memory
READY = -2             # prediction-queue sentinel: worker initialized
DROPPED = -4           # prediction-queue sentinel: batcher dropped an
                       # expired/cancelled request's segment (carries rid)

DEFAULT_SEGMENT_SIZE = 128      # paper §III: fixed to 128

# admission priority classes (index into the two-level admission queue;
# lower value = drained first)
PRIORITY_HIGH = 0
PRIORITY_NORMAL = 1
_PRIORITY_NAMES = {"high": PRIORITY_HIGH, "normal": PRIORITY_NORMAL}


class FlushBarrier:
    """An acknowledged FLUSH: the batcher closes any open coalesced slot and
    then sets ``done``.  ``InferenceSystem.quiesce(wait=True)`` and the
    reconfiguration controller's drain path use it as a barrier — unlike the
    fire-and-forget ``FLUSH`` int, the caller can block until every batcher
    has actually processed the flush (DESIGN.md §8)."""
    __slots__ = ("done",)

    def __init__(self):
        self.done = threading.Event()


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before its prediction completed."""


class RequestCancelled(Exception):
    """The request was cancelled via ``RequestHandle.cancel()``."""


class ServingUnavailable(RuntimeError):
    """Base of the transient-capacity failure taxonomy (DESIGN.md §10).

    Unlike :class:`DeadlineExceeded` (the request was too slow) these mean
    the *system* momentarily lacks the capacity to serve the request — the
    HTTP layer maps them to 503 + ``Retry-After`` so clients retry instead
    of treating them as permanent errors."""


class Overloaded(ServingUnavailable):
    """The system refused the request at admission: serving it within its
    deadline (or within the global admission byte/row budget) is infeasible
    at the current pressure (DESIGN.md §11).  Raised *before* any pipeline
    resources are consumed, so the caller can retry elsewhere immediately —
    the HTTP layer maps it to 429 with a ``Retry-After`` computed from the
    current drain estimate."""

    def __init__(self, msg: str = "overloaded",
                 retry_after_s: Optional[float] = None):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class WorkerCrashed(ServingUnavailable):
    """A worker stage thread died (or stalled past the watchdog) while the
    request had work on it and recovery could not complete it."""


class MemberUnavailable(ServingUnavailable):
    """An ensemble member the request needs has no live instance (its last
    worker was quarantined and the respawn has not landed yet)."""


class RetriesExhausted(ServingUnavailable):
    """The request's chunk-replay budget ran out: its work was resubmitted
    after worker failures more times than ``retry_budget`` allows."""


def priority_level(priority) -> int:
    """Normalize a priority spec ("high"/"normal" or the int constants)."""
    if isinstance(priority, str):
        try:
            return _PRIORITY_NAMES[priority]
        except KeyError:
            raise ValueError(f"unknown priority {priority!r} "
                             f"(expected one of {sorted(_PRIORITY_NAMES)})")
    p = int(priority)
    if p != priority or p not in (PRIORITY_HIGH, PRIORITY_NORMAL):
        raise ValueError(f"priority must be high ({PRIORITY_HIGH}) or "
                         f"normal ({PRIORITY_NORMAL}), got {priority!r}")
    return p


@dataclass(frozen=True)
class PredictOptions:
    """Per-request intent, threaded end-to-end through :class:`Request`.

    ``priority``     admission class: "high" requests drain before "normal"
                     ones (FIFO within a class) and preempt the coalescing
                     linger;
    ``deadline_ms``  relative deadline: the request fails fast with
                     :class:`DeadlineExceeded` once it expires — at
                     admission, at the batcher (rows are never packed), and
                     at the accumulator;
    ``members``      ensemble-member subset (paper §I.B "ensemble
                     selection"); None = all members;
    ``combine``      per-request combine rule (mean/weighted/vote/pallas);
                     None = the system default;
    ``cache``        prediction-cache policy for clients holding a cache:
                     "use" (lookup + fill), "bypass" (skip the cache) or
                     "refresh" (recompute and overwrite);
    ``stream``       per-segment streaming: ``on_segment(s, lo, hi, Y_seg)``
                     fires as each segment's ensemble rows complete (set
                     automatically by ``EnsembleClient.predict_stream``);
    ``member_dtype`` minimum member execution precision (DESIGN.md §14):
                     restricts the request to members running at this
                     precision *or better* (fp32 > bf16 > int8/fp8) — e.g.
                     "fp32" excludes quantized members for an
                     accuracy-critical request; None = any precision.
    """
    priority: object = "normal"
    deadline_ms: Optional[float] = None
    members: Optional[Sequence[int]] = None
    combine: Optional[str] = None
    cache: str = "use"
    stream: bool = False
    on_segment: Optional[Callable] = None
    member_dtype: Optional[str] = None

    def __post_init__(self):
        priority_level(self.priority)       # validate eagerly
        if self.cache not in ("use", "bypass", "refresh"):
            raise ValueError(f"unknown cache policy {self.cache!r}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be positive")
        if self.member_dtype is not None:
            from repro.kernels.quant import validate_member_dtype
            validate_member_dtype(self.member_dtype)

    def level(self) -> int:
        return priority_level(self.priority)

    def deadline_at(self, now: Optional[float] = None) -> Optional[float]:
        """Absolute ``perf_counter`` deadline, fixed at admission time."""
        if self.deadline_ms is None:
            return None
        return (time.perf_counter() if now is None else now) \
            + self.deadline_ms * 1e-3


def num_segments(nb_samples: int, segment_size: int) -> int:
    return (nb_samples + segment_size - 1) // segment_size


def start(s: int, segment_size: int) -> int:
    return s * segment_size


def end(s: int, segment_size: int, nb_samples: int) -> int:
    return min((s + 1) * segment_size, nb_samples)


@dataclass
class Message:
    """The {s, m, P} triplet (paper §II.C.2), tagged with the request id.

    ``m is None`` (with ``s >= 0``) marks a device-partial message whose P
    already folds ``count`` weighted member predictions.  Under coalescing a
    per-member message may carry only a row-range of its segment: ``P`` then
    covers segment rows ``[row_lo, row_lo + len(P))`` and the accumulator
    debits rows, not messages.  Sentinels use P=None."""
    s: int                       # segment id (or OOM / READY)
    m: Optional[int]             # model id; None = device partial
    P: Optional[np.ndarray]      # (rows, C) prediction matrix
    rid: int = 0                 # request id
    count: int = 1               # member contributions folded into P
    row_lo: int = 0              # first segment row covered by P

    @property
    def is_sentinel(self) -> bool:
        return self.s < 0


@dataclass
class Request:
    """One in-flight predict() call.

    ``x`` is the request's own input buffer (rows ``[:n]`` valid).  Workers
    slice it zero-copy; because the buffer belongs to the request — not the
    system — growing a later request can never invalidate it mid-flight.

    ``priority``/``deadline`` come from :class:`PredictOptions` and are read
    by every pipeline stage; ``cancel_event`` is set by
    ``RequestHandle.cancel()`` so batchers can drop still-queued descriptors
    instead of packing rows for a dead request."""
    rid: int
    x: np.ndarray                       # (capacity >= n, seq) int32
    n: int                              # valid samples
    num_classes: int
    segment_size: int
    members: List[int]                  # active ensemble members
    weights: Dict[int, float]           # member -> normalized combine weight
    combine: str = "mean"
    priority: int = PRIORITY_NORMAL
    deadline: Optional[float] = None    # absolute perf_counter seconds
    t_submit: Optional[float] = None    # admission time (perf_counter)
    retries: int = 0                    # quarantine replays charged so far
    cancel_event: threading.Event = field(default_factory=threading.Event,
                                          repr=False, compare=False)
    # Members demoted mid-flight by the brownout controller (DESIGN.md §11).
    # Mutated only by set.add (GIL-atomic); stages treat membership as
    # advisory — a unit that raced past the check is simply served, the
    # accounting closes either way.  Unlike ``cancel_event`` a demoted
    # member's work is *forgiven* (renormalized partial answer), never
    # DROPPED (which fails the whole request).
    demoted: set = field(default_factory=set, repr=False, compare=False)
    # (nbytes, rows) charged against the global AdmissionBudget; credited
    # back by the system exactly once when the request completes.
    budget_charge: Optional[tuple] = field(default=None, repr=False,
                                           compare=False)

    def num_segments(self) -> int:
        return num_segments(self.n, self.segment_size)

    def bounds(self, s: int):
        return (start(s, self.segment_size),
                end(s, self.segment_size, self.n))

    def expired(self, now: Optional[float] = None) -> bool:
        return self.deadline is not None and \
            (time.perf_counter() if now is None else now) > self.deadline

    def dropped(self) -> bool:
        """True when no stage should spend further work on this request."""
        return self.cancel_event.is_set() or self.expired()

    def demoted_for(self, m: int) -> bool:
        """True when member ``m`` was demoted off this request mid-flight
        (brownout, DESIGN.md §11): its remaining segments are forgiven
        instead of computed."""
        return bool(self.demoted) and m in self.demoted


@dataclass
class Span:
    """One contiguous row-range of one segment inside one coalesced batch.

    The batcher emits a batch as ``(buffer, [Span, ...])``; the span list is
    the scatter descriptor: batch rows ``[batch_off, batch_off + n)`` hold
    segment rows ``[seg_off, seg_off + n)`` of segment ``s`` of ``req``."""
    req: Request
    s: int                       # segment id within req
    seg_off: int                 # first row within the segment (0-based)
    batch_off: int               # first row within the batch buffer
    n: int                       # row count


class SlotRef:
    """Outstanding-chunk refcount for one flushed ring slot (DESIGN.md §3).

    The slot's chunks dispatch (and may complete) independently, but the
    underlying buffer is shared — on CPU ``device_put`` may alias host
    memory, so it can recycle only after EVERY chunk's output is
    materialized.  Each chunk calls :meth:`release` exactly once; the call
    that drops the count to zero returns True and owns the recycle.

    Deliberately lock-free: every release happens on the owning worker's
    single sender thread (skipped chunks ride the same send queue), and the
    batcher's construction happens-before via that queue — a lock here
    would cost one contended acquire per chunk on the hot path."""
    __slots__ = ("slot", "buf", "pending")

    def __init__(self, slot: Optional[int], buf: np.ndarray, pending: int):
        self.slot = slot             # ring index, or None (side-pool buffer)
        self.buf = buf
        self.pending = pending

    def release(self) -> bool:
        self.pending -= 1
        return self.pending == 0


class ChunkDesc:
    """One compiled-batch chunk cut from a flushed slot — the independently
    schedulable unit of the predictor pipeline (DESIGN.md §3).

    Slot rows ``[off, off + bucket)`` (``valid`` of them real, the tail
    zero-padded) form one jitted dispatch; ``spans`` is the scatter
    descriptor restricted to this chunk (spans never cross a compiled-batch
    boundary, so the restriction is exact).  ``level`` is the chunk's
    dispatch class — the most urgent priority among the requests whose spans
    it carries — and ``t_enq`` timestamps entry into the dispatch queue (the
    per-class ``dispatch_wait`` stage timers).  A ``__slots__`` class, not a
    dataclass: tens of thousands are created per second on the hot path."""
    __slots__ = ("ref", "off", "bucket", "valid", "spans", "level", "t_enq")

    def __init__(self, ref: SlotRef, off: int, bucket: int, valid: int,
                 spans: List[Span], level: int = PRIORITY_NORMAL,
                 t_enq: float = 0.0):
        self.ref = ref               # shared slot refcount
        self.off = off               # first slot row of this chunk
        self.bucket = bucket         # compiled (padded) batch shape
        self.valid = valid           # valid rows (<= bucket)
        self.spans = spans           # scatter descriptor, this chunk only
        self.level = level           # dispatch class
        self.t_enq = t_enq           # dispatch-queue entry (perf_counter)
