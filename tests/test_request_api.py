"""Unified request API tests (ISSUE 3): PredictOptions, the two-level
priority admission queue, deadlines, cancellation, the EnsembleClient
facade (sync / async / streaming / cache policies), adaptive linger, and
the AdaptiveBatcher timeout-leak fix."""
import queue
import threading
import time

import numpy as np
import jax
import pytest

import repro.models as M
from repro.configs import ensemble
from repro.core import AllocationMatrix, host_cpus
from repro.serving.admission import AdmissionQueue
from repro.serving.client import EnsembleClient
from repro.serving.request_cache import PredictionCache
from repro.serving.segments import (PRIORITY_HIGH, PRIORITY_NORMAL,
                                    DeadlineExceeded, PredictOptions,
                                    RequestCancelled)
from repro.serving.server import AdaptiveBatcher, _Pending
from repro.serving.system import InferenceSystem
from repro.serving.worker import ADAPTIVE_DEPTH, Worker

SEQ = 16


@pytest.fixture(scope="module")
def ens2():
    cfgs = ensemble("ENS4")[:2]
    rng = jax.random.PRNGKey(0)
    params = [M.init_params(jax.random.fold_in(rng, i), c)
              for i, c in enumerate(cfgs)]
    return cfgs, params


def oracle(cfgs, params, X, weights=None):
    import jax.numpy as jnp
    w = weights if weights is not None else [1 / len(cfgs)] * len(cfgs)
    out = np.zeros((X.shape[0], cfgs[0].vocab_size), np.float32)
    for i, (c, p) in enumerate(zip(cfgs, params)):
        fe = jnp.zeros((X.shape[0], c.frontend_tokens, c.fdim)) \
            if c.frontend_tokens else None
        lg, _ = M.forward(p, c, jnp.asarray(X), fe)
        out += np.asarray(lg[:, -1, :c.vocab_size]) * w[i]
    return out


def make_system(cfgs, params, A, **kw):
    devs = host_cpus(A.shape[0], memory_bytes=8 * 1024 ** 3)
    alloc = AllocationMatrix(devs, [c.name for c in cfgs], A)
    return InferenceSystem(cfgs, params, alloc, max_seq=SEQ, **kw)


# ---- PredictOptions ----------------------------------------------------------

def test_options_validation():
    assert PredictOptions(priority="high").level() == PRIORITY_HIGH
    assert PredictOptions().level() == PRIORITY_NORMAL
    assert PredictOptions(priority=PRIORITY_HIGH).level() == PRIORITY_HIGH
    with pytest.raises(ValueError, match="priority"):
        PredictOptions(priority="urgent")
    with pytest.raises(ValueError, match="cache"):
        PredictOptions(cache="maybe")
    with pytest.raises(ValueError, match="deadline_ms"):
        PredictOptions(deadline_ms=-5)
    assert PredictOptions().deadline_at() is None
    d = PredictOptions(deadline_ms=50).deadline_at(now=100.0)
    assert d == pytest.approx(100.05)


# ---- the admission queue -----------------------------------------------------

def test_admission_queue_priority_and_fifo():
    q = AdmissionQueue()
    q.put("n0")
    q.put("n1")
    q.put("h0", PRIORITY_HIGH)
    q.put("h1", PRIORITY_HIGH)
    assert q.qsize() == 4
    assert q.depth(PRIORITY_HIGH) == 2 and q.depth(PRIORITY_NORMAL) == 2
    # high drains first, FIFO within each class
    assert [q.get(), q.get_nowait(), q.get(0.1), q.get()] == \
        ["h0", "h1", "n0", "n1"]
    with pytest.raises(queue.Empty):
        q.get_nowait()
    with pytest.raises(queue.Empty):
        q.get(timeout=0.01)


def test_admission_queue_blocking_get():
    q = AdmissionQueue()
    got = []
    t = threading.Thread(target=lambda: got.append(q.get(timeout=5.0)))
    t.start()
    time.sleep(0.05)
    q.put("x")
    t.join(5.0)
    assert got == ["x"]


# ---- priority scheduling end-to-end ------------------------------------------

def test_high_priority_overtakes_bulk_scan(ens2):
    """A high-priority request submitted behind a saturating bulk scan
    completes while the bulk is still in flight (ROADMAP item a: no more
    strict FIFO)."""
    cfgs, params = ens2
    bulk = np.zeros((8192, SEQ), np.int32)          # 512 segments/member
    small = np.zeros((4, SEQ), np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True) as s:
        h_bulk = s.predict_async(bulk)
        h_high = s.predict_async(small,
                                 options=PredictOptions(priority="high"))
        Y = h_high.result(60.0)
        assert Y.shape == (4, cfgs[0].vocab_size)
        assert not h_bulk.done.is_set(), \
            "high-priority request should finish while the bulk scan runs"
        h_bulk.result(120.0)


def test_high_priority_preempts_linger(ens2):
    """High-priority rows collapse the linger: with an effectively-infinite
    max_wait_us a high-priority request still completes promptly instead of
    lingering in a partial batch."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=30_000_000) as s:
        t0 = time.perf_counter()
        s.predict(np.zeros((3, SEQ), np.int32), timeout=30.0,
                  options=PredictOptions(priority="high"))
        assert time.perf_counter() - t0 < 5.0


# ---- deadlines ---------------------------------------------------------------

def test_deadline_fails_fast_at_admission(ens2):
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True) as s:
        h = s.predict_async(np.zeros((4, SEQ), np.int32),
                            options=PredictOptions(deadline_ms=1e-4))
        with pytest.raises(DeadlineExceeded):
            h.result(5.0)
        # the failed admission consumed no in-flight slot / ring resources
        assert np.all(s.predict(np.zeros((4, SEQ), np.int32)) == 0)


def test_deadline_expires_in_admission_queue(ens2):
    """A deadlined request queued behind a long bulk scan fails with
    DeadlineExceeded once a batcher pops it — rows are never packed."""
    cfgs, params = ens2
    bulk = np.zeros((8192, SEQ), np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True) as s:
        rows0 = s.serving_counters().get("rows_valid", 0.0)
        s.predict_async(bulk)                        # saturate the queue
        h = s.predict_async(np.zeros((4, SEQ), np.int32),
                            options=PredictOptions(deadline_ms=1.0))
        with pytest.raises(DeadlineExceeded):
            h.result(60.0)
        # system drains and keeps serving
        assert np.all(s.predict(np.zeros((2, SEQ), np.int32),
                                timeout=120.0) == 0)
        # the expired request's rows were dropped, not dispatched: every
        # valid row belongs to the bulk scan or the follow-up request
        assert s.serving_counters()["rows_valid"] - rows0 <= \
            (8192 + 2) * len(cfgs)


# ---- cancellation ------------------------------------------------------------

def test_cancel_releases_window_and_keeps_serving(ens2):
    cfgs, params = ens2
    bulk = np.zeros((4096, SEQ), np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_in_flight=2) as s:
        h_bulk = s.predict_async(bulk)
        h2 = s.predict_async(np.zeros((4, SEQ), np.int32))
        buf2 = h2.req.x
        assert h2.cancel() is True
        with pytest.raises(RequestCancelled):
            h2.result(5.0)
        assert h2.cancel() is False            # idempotent
        # the cancelled request released its in-flight slot: with
        # max_in_flight=2 this submit would otherwise deadlock behind bulk
        h3 = s.predict_async(np.zeros((2, SEQ), np.int32))
        assert np.all(h3.result(120.0) == 0)
        h_bulk.result(120.0)
        # a cancelled request's buffer is never recycled into the pool (a
        # batcher may still read it)
        with s._pool_lock:
            assert all(b is not buf2 for b in s._buffer_pool)


def test_cancel_with_real_models_keeps_results_correct(ens2):
    """Cancelling one of several interleaved coalesced requests must not
    corrupt the surviving requests' outputs."""
    cfgs, params = ens2
    rng = np.random.default_rng(3)
    Xs = [rng.integers(0, 512, (5, SEQ)).astype(np.int32) for _ in range(6)]
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=32,
                     coalesce=True, max_in_flight=8) as s:
        handles = [s.predict_async(x) for x in Xs]
        handles[2].cancel()
        handles[4].cancel()
        for i, (x, h) in enumerate(zip(Xs, handles)):
            if i in (2, 4):
                with pytest.raises(RequestCancelled):
                    h.result(60.0)
            else:
                np.testing.assert_allclose(h.result(120.0),
                                           oracle(cfgs, params, x), atol=2e-5)


# ---- the EnsembleClient facade -----------------------------------------------

def test_client_members_and_combine_options(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(7).integers(0, 512, (20, SEQ)).astype(np.int32)
    w = np.array([0.75, 0.25], np.float32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     combine="weighted", weights=w) as s:
        client = EnsembleClient(s)
        y0 = client.predict(X, PredictOptions(members=[0]))
        y_all = client.predict(X)
        y_vote = client.predict(X, PredictOptions(combine="vote"))
    np.testing.assert_allclose(y0, oracle(cfgs[:1], params[:1], X), atol=2e-5)
    np.testing.assert_allclose(y_all, oracle(cfgs, params, X, w), atol=2e-5)
    np.testing.assert_allclose(y_vote.sum(axis=1), 1.0, atol=1e-6)


def test_client_async_handle(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(8).integers(0, 512, (10, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        client = EnsembleClient(s)
        h = client.predict_async(X)
        Y = h.result(120.0)
        assert h.done()
    np.testing.assert_allclose(Y, oracle(cfgs, params, X), atol=2e-5)


def test_client_streaming_partials(ens2):
    """predict_stream fires on_segment once per segment, in-order rows, and
    the concatenation equals the full prediction."""
    cfgs, params = ens2
    X = np.random.default_rng(9).integers(0, 512, (40, SEQ)).astype(np.int32)
    got = {}
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        client = EnsembleClient(s)
        h = client.predict_stream(
            X, lambda s_, lo, hi, Y_seg: got.setdefault(s_, (lo, hi,
                                                             Y_seg.copy())))
        Y = h.result(120.0)
    assert sorted(got) == [0, 1, 2]            # 40 rows / 16 = 3 segments
    ref = oracle(cfgs, params, X)
    for s_, (lo, hi, Y_seg) in got.items():
        assert (lo, hi) == (s_ * 16, min((s_ + 1) * 16, 40))
        np.testing.assert_allclose(Y_seg, ref[lo:hi], atol=2e-5)
    np.testing.assert_allclose(Y, ref, atol=2e-5)


def test_streaming_callback_exception_fails_request(ens2):
    """A raising on_segment callback resolves the request with that error
    instead of killing the accumulation loop; the system keeps serving."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True) as s:
        client = EnsembleClient(s)

        def boom(*a):
            raise RuntimeError("client callback exploded")

        h = client.predict_stream(np.zeros((4, SEQ), np.int32), boom)
        with pytest.raises(RuntimeError, match="exploded"):
            h.result(30.0)
        assert np.all(client.predict(np.zeros((4, SEQ), np.int32)) == 0)


def test_client_cache_policies(ens2):
    cfgs, params = ens2
    X = np.random.default_rng(11).integers(0, 512, (6, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        cache = PredictionCache(capacity=64)
        client = EnsembleClient(s, cache=cache)
        Y1 = client.predict(X)                               # fill
        msgs = s.accumulator.data_messages
        Y2 = client.predict(X)                               # all hits
        assert s.accumulator.data_messages == msgs           # no system work
        assert cache.hits == 6
        np.testing.assert_array_equal(Y1, Y2)
        client.predict(X, PredictOptions(cache="bypass"))    # skips cache
        assert s.accumulator.data_messages > msgs
        assert cache.hits == 6                               # no extra lookup
        msgs = s.accumulator.data_messages
        client.predict(X, PredictOptions(cache="refresh"))   # recompute
        assert s.accumulator.data_messages > msgs
        # partial hit: 3 cached rows + 3 new rows -> only misses submitted
        X2 = np.vstack([X[:3], X[:3] + 1])
        Y3 = client.predict(X2)
        np.testing.assert_allclose(Y3[:3], Y1[:3], atol=1e-6)
        np.testing.assert_allclose(Y3[3:], oracle(cfgs, params, X[:3] + 1),
                                   atol=2e-5)
        m = client.metrics()
        assert m["cache"]["hits"] >= 9 and "counters" in m


def test_cache_keys_are_salted_by_ensemble_config(ens2):
    """A member-subset / combine-rule request must never be answered with a
    full-ensemble cache entry: the options fingerprint salts the key."""
    cfgs, params = ens2
    X = np.random.default_rng(13).integers(0, 512, (4, SEQ)).astype(np.int32)
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16) as s:
        cache = PredictionCache(capacity=64)
        client = EnsembleClient(s, cache=cache)
        client.predict(X)                                    # full ensemble
        y0 = client.predict(X, PredictOptions(members=[0]))  # must MISS
        assert cache.hits == 0 and cache.misses == 8
        np.testing.assert_allclose(y0, oracle(cfgs[:1], params[:1], X),
                                   atol=2e-5)
        # and the subset entry is reusable under the same options
        y0b = client.predict(X, PredictOptions(members=[0]))
        assert cache.hits == 4
        np.testing.assert_array_equal(y0, y0b)
        # salts normalize: member order / explicit full set / explicit
        # system-default combine all collapse to the same key space
        assert client._cache_salt(PredictOptions(members=[1, 0])) == \
            client._cache_salt(PredictOptions(members=[0, 1]))
        assert client._cache_salt(PredictOptions(members=[0, 1])) == b""
        assert client._cache_salt(PredictOptions(combine=s.combine)) == b""


def test_client_requires_exactly_one_transport(ens2):
    with pytest.raises(ValueError, match="exactly one"):
        EnsembleClient()
    with pytest.raises(ValueError, match="exactly one"):
        EnsembleClient(object(), url="http://x")


# ---- adaptive linger ---------------------------------------------------------

def test_effective_linger_scales_with_depth():
    class Stub:
        linger_s = 0.5
        linger_mode = "adaptive"

        class input_queue:
            _d = 0

            @classmethod
            def qsize(cls):
                return cls._d

    stub = Stub()
    assert Worker._effective_linger(stub) == pytest.approx(0.5)   # idle
    Stub.input_queue._d = ADAPTIVE_DEPTH // 2
    assert Worker._effective_linger(stub) == pytest.approx(0.25)  # half
    Stub.input_queue._d = ADAPTIVE_DEPTH * 2
    assert Worker._effective_linger(stub) == 0.0                  # saturated
    stub.linger_mode = "fixed"
    assert Worker._effective_linger(stub) == pytest.approx(0.5)


def test_adaptive_linger_flushes_under_backlog(ens2):
    """With linger='adaptive' a deep queue collapses the linger: a burst of
    requests completes far faster than the configured max_wait_us would
    allow if each new slot waited out the full fixed linger."""
    cfgs, params = ens2
    with make_system(cfgs, params, np.array([[8, 8]]), segment_size=16,
                     fake=True, coalesce=True, max_wait_us=2_000_000,
                     linger="adaptive", max_in_flight=32) as s:
        handles = [s.predict_async(np.zeros((24, SEQ), np.int32))
                   for _ in range(32)]
        t0 = time.perf_counter()
        for h in handles:
            assert np.all(h.result(60.0) == 0)
        assert time.perf_counter() - t0 < 2.0   # << one 2s linger per slot


def test_linger_flag_validated(ens2):
    cfgs, params = ens2
    with pytest.raises(ValueError, match="linger"):
        make_system(cfgs, params, np.array([[8, 8]]), fake=True,
                    linger="sometimes")


# ---- AdaptiveBatcher timeout leak --------------------------------------------

class _StubSystem:
    segment_size = 4

    def __init__(self):
        self.calls = []

    def predict(self, X):
        self.calls.append(X.shape[0])
        return np.zeros((X.shape[0], 3), np.float32)


def test_adaptive_batcher_drops_cancelled_pendings():
    """A timed-out _Pending is dropped at flush time instead of being
    predicted for a waiter that already gave up."""
    sys_ = _StubSystem()
    batcher = AdaptiveBatcher(sys_, max_wait_s=0.01)
    try:
        dead = _Pending(np.zeros((2, SEQ), np.int32))
        dead.cancelled = True                  # as a submit() timeout marks it
        batcher.q.put(dead)
        y = batcher.submit(np.ones((1, SEQ), np.int32), timeout=10.0)
        assert y.shape == (1, 3)
        assert sys_.calls == [1]               # cancelled rows never predicted
    finally:
        batcher.stop()


def test_adaptive_batcher_all_cancelled_batch_is_skipped():
    sys_ = _StubSystem()
    batcher = AdaptiveBatcher(sys_, max_wait_s=0.01)
    try:
        dead = _Pending(np.zeros((2, SEQ), np.int32))
        dead.cancelled = True
        batcher.q.put(dead)
        time.sleep(0.3)
        assert sys_.calls == []                # nothing live: no predict call
        assert batcher.q.qsize() == 0          # ...but the queue drained
    finally:
        batcher.stop()
