"""The online bench: a live ``bench(A) -> score`` for the replanner.

The paper's allocator scores matrices with an *offline* bench — either the
40-second Benchmark-Mode measurement or the roofline ``AnalyticBench`` — on a
calibration workload fixed before deployment.  At runtime the real workload
drifts: members run hotter or colder than the bench profile assumed, and the
measured per-worker latencies embed effects no roofline captures (GIL
contention, co-location interference, cache behavior).  ``LiveBench`` keeps
two continuously-updated views (DESIGN.md §8):

* a **latency profile** — an EWMA of per-batch wall time keyed by
  ``(member, device key, compiled bucket)``, fed by every worker's sender
  (dispatch-to-materialized, attributed to chunks by dispatched rows);
* **demand shares** — a decayed per-member row count fed by the
  broadcaster, so ensemble-selection traffic (``members=[...]`` subsets)
  shows up as per-member load skew.

Called as a ``Bench`` it mirrors ``AnalyticBench``'s structure — co-located
workers time-share their device, a model's throughput adds over instances —
but uses measured latencies where available (falling back to the roofline
for never-observed placements) and weights the final min by demand shares:
a member carrying 4x the traffic needs 4x the throughput before it stops
being the bottleneck.  Scores stay comparable across candidates, which is
all the bounded greedy needs.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import memory as mem
from repro.core.allocation import AllocationMatrix
from repro.core.bench import AnalyticBench, per_model_throughput
from repro.core.devices import DeviceSpec

# below this fraction of a measured bucket, extrapolated latency stops
# shrinking: per-batch dispatch overhead puts a floor under small buckets
OVERHEAD_FLOOR = 0.25


class LiveBench:
    """EWMA latency/demand profile over the serving hot path, callable as a
    ``Bench``.  ``observe``/``note_request`` are called from worker sender
    threads and the broadcaster; scoring runs on the controller thread —
    all state is guarded by one lock (the critical sections are tiny)."""

    def __init__(self, cfgs: Sequence[ModelConfig], *, seq: int = 128,
                 alpha: float = 0.25, demand_decay: float = 0.999,
                 dtype_bytes: int = 4,
                 fallback: Optional[AnalyticBench] = None,
                 member_dtypes: Optional[Sequence[Optional[str]]] = None):
        self.cfgs = list(cfgs)
        self.seq = seq
        self.alpha = alpha
        self.demand_decay = demand_decay
        self.dtype_bytes = dtype_bytes
        # per-member execution dtype (DESIGN.md §14): quantized members'
        # smaller param footprint feeds fit_mem and the roofline fallback
        self.member_dtypes = list(member_dtypes) if member_dtypes else None
        self.fallback = fallback or AnalyticBench(
            cfgs, seq=seq, dtype_bytes=dtype_bytes,
            member_dtypes=member_dtypes)
        self._lock = threading.Lock()
        self._lat: Dict[Tuple[int, str, int], float] = {}
        # uniform prior: demand shares start equal and drift with traffic
        self._demand = np.ones(len(self.cfgs), np.float64)
        # forecast-fed shares (DESIGN.md §12): (shares, expires_at) or None.
        # ``clock`` is overridable so the simulator can expire forecasts on
        # virtual time; everything else in this class is time-free.
        self._forecast: Optional[Tuple[np.ndarray, float]] = None
        self.forecasts = 0
        self.clock = time.perf_counter
        self.observations = 0
        self.requests = 0
        self.calls = 0

    # ---- the feeds (hot path) ------------------------------------------------
    def observe(self, m: int, dev_key: str, bucket: int, rows: int,
                dt: float) -> None:
        """One compiled-batch completion: ``rows`` valid rows of member ``m``
        ran in a ``bucket``-row batch on the device in ``dt`` seconds."""
        if rows <= 0 or dt <= 0.0:
            return
        key = (m, dev_key, int(bucket))
        with self._lock:
            old = self._lat.get(key)
            self._lat[key] = dt if old is None else \
                (1.0 - self.alpha) * old + self.alpha * dt
            self.observations += 1

    def note_request(self, members: Sequence[int], rows: int) -> None:
        """One admitted request: ``rows`` rows for each member in the
        request's (possibly subset) member list."""
        with self._lock:
            self.requests += 1
            self._demand *= self.demand_decay
            for m in members:
                self._demand[m] += rows

    def set_forecast(self, shares: Sequence[float], *,
                     ttl_s: float = 5.0) -> None:
        """Install predicted per-member demand shares (item j).  While the
        forecast is *fresh* (within ``ttl_s`` of ``self.clock()``) it
        replaces the trailing EWMA in :meth:`demand_shares`; once stale the
        profile falls back to the decayed EWMA, which kept updating the
        whole time — a dead forecaster degrades to pre-forecast behavior
        rather than freezing the planner on an old prediction."""
        s = np.asarray(shares, np.float64)
        if s.shape != (len(self.cfgs),) or (s < 0).any() or s.sum() <= 0:
            raise ValueError(f"forecast shares must be {len(self.cfgs)} "
                             f"non-negative values, got {shares!r}")
        with self._lock:
            self._forecast = (s / s.sum(), self.clock() + float(ttl_s))
            self.forecasts += 1

    def forecast_fresh(self) -> bool:
        with self._lock:
            return (self._forecast is not None
                    and self.clock() < self._forecast[1])

    # ---- the profile ---------------------------------------------------------
    def demand_shares(self) -> np.ndarray:
        with self._lock:
            if self._forecast is not None:
                shares, expires = self._forecast
                if self.clock() < expires:
                    return shares.copy()
                self._forecast = None       # stale: drop, fall back to EWMA
            d = self._demand.copy()
        return d / d.sum()

    def _measured_latency(self, m: int, dev_key: str,
                          bucket: int) -> Optional[float]:
        """Measured per-batch latency estimate for (member, device, bucket):
        the exact EWMA, else the nearest measured bucket scaled by the batch
        ratio with an overhead floor (per-batch dispatch cost puts a floor
        under small buckets).  None when this (member, device) was never
        observed."""
        with self._lock:
            dt = self._lat.get((m, dev_key, bucket))
            if dt is not None:
                return dt
            near = [(abs(b - bucket), b, t) for (mm, kk, b), t
                    in self._lat.items() if mm == m and kk == dev_key]
        if not near:
            return None
        _, b, t = min(near)
        return t * max(bucket / b, OVERHEAD_FLOOR)

    def segment_time(self, m: int, dev_key: str, batch: int,
                     segment_size: int) -> Optional[float]:
        """Estimated wall time for one ``segment_size``-row segment of
        member ``m`` on a ``batch``-sized worker: measured per-chunk EWMA x
        chunks per segment.  Returns None when nothing relevant was measured
        yet — the caller (the work stealer) then treats siblings as
        equal-rate instead of trusting the roofline."""
        per_chunk = self._measured_latency(m, dev_key, batch)
        if per_chunk is None:
            return None
        return per_chunk * max(1, -(-segment_size // batch))

    def worker_time(self, dev: DeviceSpec, m: int, bucket: int) -> float:
        """Expected per-batch latency for (member, device, bucket): the
        measured estimate when available, the roofline fallback for
        never-observed placements."""
        dt = self._measured_latency(m, dev.key(), bucket)
        if dt is not None:
            return dt
        return self.fallback.worker_time(
            dev, self.cfgs[m], bucket,
            self.member_dtypes[m] if self.member_dtypes else None)

    # ---- the Bench -----------------------------------------------------------
    def __call__(self, alloc: AllocationMatrix) -> float:
        """Demand-weighted live throughput estimate of matrix ``alloc`` (same
        0.0-for-infeasible convention as the offline benches).  Uniform
        demand reduces to ``AnalyticBench``'s plain min-over-members."""
        self.calls += 1
        if not alloc.is_valid():
            return 0.0
        if not mem.fit_mem(alloc, self.cfgs, self.seq, self.dtype_bytes,
                           member_dtypes=self.member_dtypes):
            return 0.0
        per_model = per_model_throughput(
            alloc, lambda d, m, b: self.worker_time(alloc.devices[d], m, b))
        shares = self.demand_shares() * len(self.cfgs)
        return min(thr / shares[m] for m, thr in enumerate(per_model))

    def snapshot(self) -> dict:
        """Observability view for ``/metrics`` (DESIGN.md §8)."""
        with self._lock:
            lat = {f"m{m}|{k}|b{b}": round(t, 6)
                   for (m, k, b), t in sorted(self._lat.items())}
        return {"observations": self.observations,
                "requests": self.requests,
                "bench_calls": self.calls,
                "forecasts": self.forecasts,
                "forecast_fresh": self.forecast_fresh(),
                "demand_shares": [round(float(s), 4)
                                  for s in self.demand_shares()],
                "latency_ewma_s": lat}
