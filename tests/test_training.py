"""Training substrate: convergence, grad accumulation, checkpointing,
optimizer math, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_config
from repro.data.pipeline import PrefetchIterator, SyntheticLM
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step, train


def test_loss_decreases_on_ngram():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(cfg.vocab_size, 32, task="ngram").iterator(16, cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=80)
    _, hist = train(cfg, params, data, ocfg, steps=80, log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0


def test_grad_accum_equivalence():
    cfg = get_config("llama3-8b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    ocfg = opt.AdamWConfig()
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLM(cfg.vocab_size, 16).batch(8).items()}
    s1 = jax.jit(make_train_step(cfg, ocfg, accum_steps=1, remat=False))
    s4 = jax.jit(make_train_step(cfg, ocfg, accum_steps=4, remat=False))
    p1, _, m1 = s1(params, state, batch)
    p4, _, m4 = s4(params, state, batch)
    assert abs(float(m1["ce"]) - float(m4["ce"])) < 1e-4
    deltas = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4)
    assert max(jax.tree.leaves(deltas)) < 1e-4


def test_remat_equivalence():
    cfg = get_config("gemma3-1b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    ocfg = opt.AdamWConfig()
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLM(cfg.vocab_size, 16).batch(4).items()}
    pa, _, ma = jax.jit(make_train_step(cfg, ocfg, remat=False))(params, state, batch)
    pb, _, mb = jax.jit(make_train_step(cfg, ocfg, remat=True))(params, state, batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 1e-5
    deltas = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), pa, pb)
    assert max(jax.tree.leaves(deltas)) < 1e-5


def test_schedule_shape():
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                           min_lr_ratio=0.1)
    lrs = [float(opt.schedule(ocfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4              # peak after warmup
    assert lrs[-1] < 2.0e-4                        # decays toward min ratio
    assert lrs[-1] >= 1e-4 - 1e-9


def test_grad_clip():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = opt.init(params)
    ocfg = opt.AdamWConfig(grad_clip=1.0)
    _, _, m = opt.apply(ocfg, params, grads, state)
    assert float(m["grad_norm"]) == pytest.approx(400.0)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("mamba2-1.3b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    tree = {"params": params, "opt": state}
    ckpt.save(str(tmp_path), 7, tree)
    restored = ckpt.restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_checkpoint_prune_and_structure_check(tmp_path):
    cfg = get_config("musicgen-large").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, params, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), params, step=1)      # pruned
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), {"different": params["embed"]})


def test_ngram_task_is_learnable_structure():
    gen = SyntheticLM(64, 32, task="ngram", seed=1)
    b = gen.batch(4)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # each token has at most 8 successors (sparse bigram)
    succ = {}
    big = gen.batch(64)
    seq = np.concatenate([big["tokens"], big["labels"][:, -1:]], axis=1)
    for row in seq:
        for a, b_ in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b_))
    assert max(len(v) for v in succ.values()) <= 8


def test_prefetch_iterator():
    it = PrefetchIterator(SyntheticLM(32, 8).iterator(2), depth=2)
    batches = [next(it) for _ in range(5)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    it.close()
