"""Two-level priority queues for the worker pipeline (DESIGN.md §§3/7).

:class:`AdmissionQueue` (ROADMAP item a) is the worker batcher's input
queue: latency-sensitive requests must not wait behind a bulk scan, so
admission is class-based instead of strict FIFO — ``PRIORITY_HIGH``
descriptors drain before ``PRIORITY_NORMAL`` ones, FIFO *within* each class
(no reordering among equals, so the sender's row-count span reassembly
stays trivially correct: all of one segment's spans are packed in one
batcher iteration either way).

:class:`DispatchQueue` (ROADMAP items e/k) sits *between the batcher and
the predictor*: a flushed slot's compiled chunks enter it as independently
schedulable :class:`~repro.serving.segments.ChunkDesc` units, classed by
:func:`chunk_level`, so a high-priority chunk jumps queued bulk chunks
instead of waiting for up to ``RING_SLOTS`` already-flushed slots.  Only
the single chunk already dispatched to the device (plus the dispatch-ahead
window) is non-preemptible.

The interface mirrors the ``queue.Queue`` subset the consumers use
(``put`` / ``get(timeout)`` / ``get_nowait`` / ``qsize``) so control
sentinels (``SHUTDOWN`` / ``FLUSH`` / barriers) flow through unchanged at
normal priority.  Starvation is not a concern at this queue's time scale:
high priority is meant for sparse latency-sensitive traffic, and a
saturating high-priority flood is an admission-control problem upstream of
the worker.
"""
from __future__ import annotations

import heapq
import queue
import threading
from collections import deque
from typing import Optional, Sequence

from repro.serving.segments import (PRIORITY_HIGH, PRIORITY_NORMAL,
                                    ChunkDesc, Span, priority_level)


def chunk_level(spans: Sequence[Span]) -> int:
    """Dispatch class of a chunk: the most urgent priority among the
    requests whose spans it carries (reusing the admission
    ``priority_level`` scale, where lower = more urgent).  A bulk chunk
    that coalesced even one high-priority request's rows dispatches at high
    priority — holding those rows back would defeat the preemption."""
    level = PRIORITY_NORMAL
    for sp in spans:
        level = min(level, priority_level(sp.req.priority))
        if level == PRIORITY_HIGH:
            break
    return level


class AdmissionBudget:
    """Bounded global byte/row budget for admitted-but-unfinished work
    (DESIGN.md §11 backpressure).

    The admission queues themselves stay unbounded (sentinels and control
    items must never block), but the *request payloads* feeding them are
    charged here at admission and credited back at completion, so queue
    memory cannot grow without bound under sustained overload: once the
    budget is exhausted new requests fail fast with
    :class:`~repro.serving.segments.Overloaded` (HTTP 429) instead of
    piling onto a queue they would only time out in.  ``rows`` counts
    request rows x planned members — the same unit the accumulator debits —
    so the row budget bounds pipeline work, while the byte budget bounds
    input-buffer memory."""

    def __init__(self, max_bytes: Optional[int] = None,
                 max_rows: Optional[int] = None):
        self.max_bytes = max_bytes
        self.max_rows = max_rows
        self._lock = threading.Lock()
        self.bytes_used = 0
        self.rows_used = 0
        self.rejected = 0

    def try_charge(self, nbytes: int, rows: int) -> bool:
        """Atomically charge, or refuse without side effects.  A single
        request larger than the whole budget is still admitted when the
        budget is idle (otherwise it could never run)."""
        with self._lock:
            idle = self.bytes_used == 0 and self.rows_used == 0
            over_b = self.max_bytes is not None and \
                self.bytes_used + nbytes > self.max_bytes
            over_r = self.max_rows is not None and \
                self.rows_used + rows > self.max_rows
            if (over_b or over_r) and not idle:
                self.rejected += 1
                return False
            self.bytes_used += nbytes
            self.rows_used += rows
            return True

    def credit(self, nbytes: int, rows: int) -> None:
        with self._lock:
            self.bytes_used = max(0, self.bytes_used - nbytes)
            self.rows_used = max(0, self.rows_used - rows)

    def snapshot(self) -> dict:
        with self._lock:
            return {"bytes_used": self.bytes_used,
                    "rows_used": self.rows_used,
                    "max_bytes": self.max_bytes,
                    "max_rows": self.max_rows,
                    "rejected": self.rejected}


class AdmissionQueue:
    """Unbounded two-level MPSC queue with ``queue.Queue``-style blocking.

    ``trace_hook`` (optional, wired by the system when tracing is on) is
    called as ``hook(kind, items, level)`` after batch transitions —
    ``"enqueue"`` on :meth:`put_many`, ``"steal"`` / ``"drain"`` after
    work moves between workers — so the tracer can annotate timelines
    with queue-level control-plane facts.  Hooks run outside the queue
    lock; when unset the cost is one attribute check."""

    trace_hook = None

    def __init__(self):
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._levels = {PRIORITY_HIGH: deque(), PRIORITY_NORMAL: deque()}

    def put(self, item, priority: int = PRIORITY_NORMAL) -> None:
        with self._not_empty:
            self._levels[priority].append(item)
            self._not_empty.notify()

    def put_many(self, items, priority: int = PRIORITY_NORMAL) -> None:
        """Enqueue a batch of items at one level under ONE lock acquisition
        (the batcher flushes a slot's chunks together — per-item locking
        would multiply queue overhead by chunks-per-slot)."""
        if not items:
            return
        with self._not_empty:
            self._levels[priority].extend(items)
            self._not_empty.notify()
        hook = self.trace_hook
        if hook is not None:
            hook("enqueue", items, priority)

    def _pop(self):
        for level in (PRIORITY_HIGH, PRIORITY_NORMAL):
            q = self._levels[level]
            if q:
                return q.popleft()
        raise queue.Empty

    def get(self, timeout: Optional[float] = None):
        with self._not_empty:
            if timeout is None:
                while not self._size_locked():
                    self._not_empty.wait()
            elif not self._not_empty.wait_for(self._size_locked, timeout):
                raise queue.Empty
            return self._pop()

    def get_nowait(self):
        with self._lock:
            return self._pop()

    def take_high(self):
        """Atomically pop the head HIGH-priority *descriptor*, or return
        None when the high class is empty or its head is not a descriptor
        tuple.  The batcher's preemptible bulk-slot wait uses this: a bare
        depth-check-then-get would race ``drain_descriptors`` (drain-side
        migration empties BOTH classes under the queue lock) and either
        raise Empty or swallow a sentinel the batcher still owes an ack
        for."""
        with self._lock:
            q = self._levels[PRIORITY_HIGH]
            if q and isinstance(q[0], tuple):
                return q.popleft()
            return None

    def get_batch(self, max_items: int):
        """Block for the first item, then pop up to ``max_items`` under ONE
        lock acquisition, strictly in priority order (all available high
        items drain before any normal one).  The consumer-side twin of
        :meth:`put_many`: per-item locking on a hot hand-off path costs a
        contended lock round per item for no scheduling benefit when the
        caller is about to commit the whole batch anyway."""
        with self._not_empty:
            while not self._size_locked():
                self._not_empty.wait()
            out = []
            while len(out) < max_items and self._size_locked():
                out.append(self._pop())
            return out

    def _size_locked(self) -> int:
        return len(self._levels[PRIORITY_HIGH]) + \
            len(self._levels[PRIORITY_NORMAL])

    def qsize(self) -> int:
        with self._lock:
            return self._size_locked()

    def steal(self, max_items: int) -> list:
        """Pop up to ``max_items`` normal-priority segment descriptors from
        the stealable tail region (DESIGN.md §8: cross-worker work
        stealing).  The sweep walks tail-ward until it meets a
        non-descriptor item and stops there: it can only take descriptors
        enqueued *after* the last sentinel, and a queue whose tail IS a
        sentinel (``SHUTDOWN`` / ``FLUSH`` just posted — the worker is
        draining or being quiesced) yields nothing.  Sentinels themselves
        are never popped or reordered, and the victim's head — what its
        batcher is about to drain — is never contended.

        Within the stealable region selection is **deadline-aware** (ROADMAP
        item i): descriptors whose requests have the tightest remaining
        deadline budget are picked first — they gain the most from the idle
        sibling — and deadline-less descriptors rank loosest, newest first
        (the work that would otherwise wait longest, the classic tail-steal
        order).  The returned list drains tightest-deadline work first
        (FIFO among equals), so re-putting at the destination serves urgent
        work soonest.  Atomic with respect to the consumer: a descriptor is
        owned either by the thief or by the batcher, never both."""
        with self._lock:
            q = self._levels[PRIORITY_NORMAL]
            first = len(q)
            any_deadline = False
            while first > 0 and isinstance(q[first - 1], tuple):
                first -= 1
                if getattr(q[first][0], "deadline", None) is not None:
                    any_deadline = True
            if first == len(q):
                return []
            if not any_deadline:
                # common case (bulk work carries no deadlines): the classic
                # O(max_items) tail pop — no sort, no region rebuild, and
                # the victim's batcher contends this lock on its hot path
                stolen = []
                while q and len(stolen) < max_items and \
                        isinstance(q[-1], tuple):
                    stolen.append(q.pop())
                stolen.reverse()
                return self._stolen(stolen)

            def urgency(i):          # (no-deadline flag, deadline) ascending
                d = getattr(q[i][0], "deadline", None)
                return (d is None, d or 0.0)

            chosen = heapq.nsmallest(max_items, range(first, len(q)),
                                     key=lambda i: urgency(i) + (-i,))
            chosen.sort(key=lambda i: urgency(i) + (i,))
            stolen = [q[i] for i in chosen]
            take = set(chosen)
            kept = [q[i] for i in range(first, len(q)) if i not in take]
            for _ in range(len(q) - first):
                q.pop()
            q.extend(kept)
        return self._stolen(stolen)

    def _stolen(self, stolen: list) -> list:
        hook = self.trace_hook
        if hook is not None and stolen:
            hook("steal", stolen, None)
        return stolen

    def drain_descriptors(self) -> list:
        """Pop EVERY queued segment descriptor, both priority classes
        (drain-side instance migration — unlike :meth:`steal`, a retiring
        worker's latency-sensitive work must move too, or exactly the
        high-priority class would pay the victim's full drain latency).
        High-priority descriptors first, FIFO within each class; re-putting
        with each request's own priority restores class order at the
        destination.  Sentinels (``SHUTDOWN``/``FLUSH``/barriers) stay in
        place in their relative order — the retiring batcher still owes
        their acknowledgements."""
        out = []
        with self._lock:
            for level in (PRIORITY_HIGH, PRIORITY_NORMAL):
                keep = deque()
                for item in self._levels[level]:
                    (out if isinstance(item, tuple) else keep).append(item)
                self._levels[level] = keep
        hook = self.trace_hook
        if hook is not None and out:
            hook("drain", out, None)
        return out

    def depth(self, priority: int) -> int:
        """Backlog of one class (the ``queue_depth.<worker>`` gauge uses
        ``qsize``; per-class depth feeds tests and adaptive linger)."""
        with self._lock:
            return len(self._levels[priority])


class DispatchQueue(AdmissionQueue):
    """The per-worker chunk dispatch queue between batcher and predictor
    (DESIGN.md §3): items are :class:`~repro.serving.segments.ChunkDesc`
    units ``put`` at their :func:`chunk_level` class — high-priority chunks
    jump queued bulk chunks, FIFO within a class — plus pipeline control
    items at normal priority (``None`` shutdown sentinel, ``FlushBarrier``
    acknowledged by the predictor once every previously-flushed chunk has
    been dispatched).  Chunks are never stolen or migrated: their rows are
    already packed into this worker's ring slots, so re-routing happens one
    stage earlier, on the :class:`AdmissionQueue`."""

    def steal(self, max_items: int) -> list:
        raise TypeError("chunks are bound to their worker's ring slots; "
                        "steal from the AdmissionQueue instead")

    def drain_descriptors(self) -> list:
        raise TypeError("chunks are bound to their worker's ring slots; "
                        "migrate AdmissionQueue descriptors instead")


def chunk_deadline(chunk: ChunkDesc) -> float:
    """Earliest absolute deadline among the requests whose spans the chunk
    carries; +inf when none of them has one."""
    d = float("inf")
    for sp in chunk.spans:
        rd = sp.req.deadline
        if rd is not None and rd < d:
            d = rd
    return d


class EDFDispatchQueue(DispatchQueue):
    """Earliest-deadline-first chunk dispatch (ROADMAP item m, prototype).

    Replaces the two static dispatch classes with a single heap ordered by
    ``(chunk deadline, chunk_level, enqueue seq)``: the chunk whose
    tightest-deadline request expires soonest dispatches first; deadline
    ties fall back to the existing priority classes, then FIFO.
    Deadline-less chunks rank at +inf, so a pure two-class workload behaves
    exactly like :class:`DispatchQueue` (the EDF order degenerates to
    class-then-FIFO) — EDF only changes behavior when deadlines actually
    differentiate the backlog.

    Control items (the ``None`` shutdown sentinel, ``FlushBarrier``) keep
    FIFO order in a side lane and are released only once every queued chunk
    has dispatched — a conservative barrier: EDF may reorder chunks
    *between* flushes, so a barrier that overtook a reordered chunk would
    acknowledge a flush that has not fully dispatched yet.

    Status: validated in the simulator (DESIGN.md §12; `sim.edf` bench
    gate) and wired into the live Worker behind
    ``--dispatch-queue edf`` (``InferenceSystem(dispatch_queue="edf")``);
    the live default remains :class:`DispatchQueue` (FIFO within
    priority class)."""

    def __init__(self):
        super().__init__()
        self._eheap = []                      # (deadline, level, seq, chunk)
        self._eseq = 0
        self._control = deque()

    def _push_locked(self, item) -> None:
        if isinstance(item, ChunkDesc):
            self._eseq += 1
            heapq.heappush(self._eheap, (chunk_deadline(item), item.level,
                                         self._eseq, item))
        else:
            self._control.append(item)

    def put(self, item, priority: int = PRIORITY_NORMAL) -> None:
        with self._not_empty:
            self._push_locked(item)
            self._not_empty.notify()

    def put_many(self, items, priority: int = PRIORITY_NORMAL) -> None:
        if not items:
            return
        with self._not_empty:
            for item in items:
                self._push_locked(item)
            self._not_empty.notify()
        hook = self.trace_hook
        if hook is not None:
            hook("enqueue", items, priority)

    def _pop(self):
        if self._eheap:
            return heapq.heappop(self._eheap)[3]
        if self._control:
            return self._control.popleft()
        raise queue.Empty

    def _size_locked(self) -> int:
        return len(self._eheap) + len(self._control)

    def depth(self, priority: int) -> int:
        with self._lock:
            if priority == PRIORITY_HIGH:
                return sum(1 for e in self._eheap
                           if e[1] == PRIORITY_HIGH)
            return len(self._eheap) + len(self._control) - sum(
                1 for e in self._eheap if e[1] == PRIORITY_HIGH)

    def take_high(self):
        return None                 # no side lane to express-pop from
