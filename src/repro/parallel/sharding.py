"""Logical sharding rules: param/cache/batch pytrees -> PartitionSpec trees.

Strategy (DESIGN.md §5): batch over ("pod","data"), width over "model".
Every rule is a preference list of (dim, mesh-axis) candidates; the first
candidate whose dimension size divides the axis size wins, otherwise the
tensor is replicated — so every assigned architecture lowers on the
production mesh regardless of head/expert divisibility quirks.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

# name -> preference list of (dim, axis) in LAYER-LOCAL coords (no repeats dim)
_PARAM_RULES: Dict[str, List[Tuple[int, str]]] = {
    "embed":    [(0, "model")],
    "head":     [(1, "model")],
    "wq":       [(1, "model"), (2, "model")],
    "wk":       [(1, "model"), (2, "model")],
    "wv":       [(1, "model"), (2, "model")],
    "wo":       [(0, "model"), (1, "model")],
    # dense mlp
    "w_gate":   [(1, "model")],          # (D,F) — overridden for MoE below
    "w_up":     [(1, "model")],
    "w_down":   [(0, "model")],
    "ws_gate":  [(1, "model")],
    "ws_up":    [(1, "model")],
    "ws_down":  [(0, "model")],
    # moe experts (E,D,F)/(E,F,D)
    "w_gate_moe": [(0, "model"), (2, "model")],
    "w_up_moe":   [(0, "model"), (2, "model")],
    "w_down_moe": [(0, "model"), (1, "model")],
    # ssm
    "in_proj":  [(1, "model")],
    "out_proj": [(0, "model")],
}


def _pick(shape: Sequence[int], prefs: List[Tuple[int, str]], mesh) -> P:
    spec: List[Optional[str]] = [None] * len(shape)
    for dim, axis in prefs:
        if axis in mesh.axis_names and dim < len(shape) and \
                shape[dim] % mesh.shape[axis] == 0:
            spec[dim] = axis
            return P(*spec)
    return P(*spec)


def param_specs(cfg: ModelConfig, shapes, mesh):
    """PartitionSpec tree matching transformer.param_shapes(cfg)."""
    moe = cfg.moe is not None

    from repro import runtime_flags
    repl_small = runtime_flags.SHARDING_OPTS.get("attn_replicate_small_heads")
    fsdp = runtime_flags.SHARDING_OPTS.get("fsdp_params")

    def _add_fsdp(spec: P, shape) -> P:
        """§Perf variant "fsdp": additionally shard one free dim over "data"
        (ZeRO-3 for params + optimizer state).  Without it a 100B-class MoE's
        param+AdamW state is replicated across the data axis and overflows
        HBM (llama4-scout: 67.4 GB/chip vs 16 GB — EXPERIMENTS.md §Perf)."""
        if not fsdp or "data" not in mesh.axis_names or len(shape) < 2:
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        order = sorted(range(len(shape)), key=lambda d: -shape[d])
        for dim in order:
            if parts[dim] is None and shape[dim] % mesh.shape["data"] == 0:
                parts[dim] = "data"
                return P(*parts)
        return spec

    def leaf_spec(name: str, shape, stacked: bool) -> P:
        key = name
        if moe and name in ("w_gate", "w_up", "w_down") and stacked:
            key = name + "_moe"
        prefs = _PARAM_RULES.get(key, [])
        if stacked:    # leading repeats dim is never sharded
            prefs = [(d + 1, a) for d, a in prefs]
        if repl_small and name in ("wq", "wk", "wv", "wo") and prefs:
            # §Perf variant: when the head-count dim doesn't divide the model
            # axis, replicate the (tiny) attention projections rather than
            # shard head_dim — kills the per-chunk psum in attention.
            head_dim_idx, axis = prefs[0]
            if shape[head_dim_idx] % mesh.shape[axis] != 0:
                return _add_fsdp(P(*([None] * len(shape))), shape)
        return _add_fsdp(_pick(shape, prefs, mesh), shape)

    out = {}
    for name, node in shapes.items():
        if name == "layers":
            out["layers"] = [
                {k: leaf_spec(k, v, True) for k, v in unit.items()}
                for unit in node
            ]
        else:
            out[name] = leaf_spec(name, node, False)
    return out


def param_shardings(cfg: ModelConfig, mesh):
    from repro.models.transformer import param_shapes
    shapes = param_shapes(cfg)
    specs = param_specs(cfg, shapes, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, global_batch: int, ndim: int = 2, *,
               seq_dim: Optional[int] = None, seq_len: int = 0) -> P:
    """Shard the leading batch dim over ("pod","data") when divisible;
    otherwise (long_500k, batch=1) shard the sequence dim over "data"."""
    axes = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    spec: List = [None] * ndim
    if global_batch % size == 0:
        spec[0] = axes if len(axes) > 1 else axes[0]
    elif seq_dim is not None and seq_len and "data" in mesh.axis_names and \
            seq_len % mesh.shape["data"] == 0:
        spec[seq_dim] = "data"
    return P(*spec)


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int):
    """PartitionSpec tree matching models.cache.cache_struct."""
    from repro.models.cache import layer_cache_struct
    axes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in axes]))
    b_ax = (axes if len(axes) > 1 else axes[0]) if batch % bsize == 0 else None
    seq_ok = b_ax is None and "data" in mesh.axis_names

    from repro import runtime_flags
    seq_shard = runtime_flags.SHARDING_OPTS.get("decode_cache_seq")
    repl_small = runtime_flags.SHARDING_OPTS.get("attn_replicate_small_heads")

    def kv_spec(shape):   # (R,B,L,KV,hd)
        spec = [None, b_ax, None, None, None]
        if seq_ok and shape[2] % mesh.shape["data"] == 0:
            spec[2] = "data"
        if seq_shard and shape[2] % mesh.shape["model"] == 0:
            # §Perf variant: flash-decoding layout — each chip owns an L/16
            # slice of the cache; attention reads are local, softmax combines
            # via tiny psums instead of all-gathering the cache.
            if spec[2] == "data" and \
                    shape[2] % (mesh.shape["data"] * mesh.shape["model"]) == 0:
                spec[2] = ("data", "model")
            else:
                spec[2] = "model"
            return P(*spec)
        # KV heads over model; when heads don't divide and attn_repl is on,
        # prefer a sequence-sharded cache (head_dim sharding would propagate
        # back into q/k/v and reintroduce per-chunk psums), else head_dim.
        if shape[3] % mesh.shape["model"] == 0:
            spec[3] = "model"
        elif repl_small:
            if spec[2] is None and shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        elif shape[4] % mesh.shape["model"] == 0:
            spec[4] = "model"
        return P(*spec)

    def ssm_h_spec(shape):  # (R,B,H,P,N)
        spec = [None, b_ax, None, None, None]
        if shape[2] % mesh.shape["model"] == 0:
            spec[2] = "model"
        elif shape[3] % mesh.shape["model"] == 0:
            spec[3] = "model"
        return P(*spec)

    def conv_spec(shape):   # (R,B,K-1,C)
        spec = [None, b_ax, None, None]
        if shape[3] % mesh.shape["model"] == 0:
            spec[3] = "model"
        return P(*spec)

    layers = []
    for kind in cfg.pattern:
        entry = {}
        from repro import runtime_flags as _rf
        struct = layer_cache_struct(
            cfg, kind, batch, max_len,
            quantized=bool(_rf.SHARDING_OPTS.get("kv_quant")))
        for name, (shape, _) in struct.items():
            full = (cfg.repeats,) + shape
            if name in ("k", "v", "k_scale", "v_scale"):
                entry[name] = kv_spec(full)
            elif name == "h":
                entry[name] = ssm_h_spec(full)
            else:
                entry[name] = conv_spec(full)
        layers.append(entry)
    return {"layers": layers}


def to_named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
